"""Training protocols for the page predictor (Sections III-C, IV-B, V-A/B).

  * online_single — ONE model, plain CE, train on group k-1 / predict group k
                    (the existing-learning-based-works protocol, Fig. 4).
  * online_multi  — pattern-aware model table, plain CE (Fig. 6 'multiple').
  * ours          — pattern-aware table + LUCIR distillation + (optionally)
                    the thrashing term (the full Section IV design).
  * offline       — train one model on a random 50% of samples (future info!)
                    then predict everything in temporal order: the paper's
                    upper bound (Figs. 4/11).

Every protocol measures top-1 accuracy on a group BEFORE the model trains on
it (strictly causal evaluation).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.predictor_paper import PredictorConfig
from repro.core import losses
from repro.core.baselines_nn import make_model
from repro.core.features import DeltaVocab, FeatureSet, FeatureStream
from repro.core.model_table import Entry, ModelTable
from repro.core.pattern import PatternClassifier
from repro.optim import adamw
from repro.uvm.trace import Trace


@dataclasses.dataclass
class TrainConfig:
    group_size: int = 2048  # accesses per train/predict group (paper: 50M instr)
    epochs: int = 3
    batch_size: int = 256
    lr: float = 3e-3
    seed: int = 0
    table_slots: int = 8


def _batch_of(fs: FeatureSet, idx) -> dict:
    return {
        "page": jnp.asarray(fs.page[idx]),
        "delta": jnp.asarray(fs.delta[idx]),
        "pc": jnp.asarray(fs.pc[idx]),
        "tb": jnp.asarray(fs.tb[idx]),
    }


class Trainer:
    """Jitted train/eval for one predictor architecture."""

    def __init__(self, pcfg: PredictorConfig, tcfg: TrainConfig, kind: str = "transformer"):
        self.pcfg, self.tcfg, self.kind = pcfg, tcfg, kind
        self.init_fn, self.forward = make_model(pcfg, kind)
        self.opt = adamw.adamw(tcfg.lr, weight_decay=0.01)

        def train_step(params, opt_state, batch, labels, n_active, step, f_old, in_et, use_lucir, use_thrash):
            def lf(p):
                logits, f = self.forward(p, batch)
                return losses.total_loss(
                    logits, f, labels,
                    n_active=n_active,
                    f_old=f_old if use_lucir else None,
                    in_et=in_et if use_thrash else None,
                    lam=self.pcfg.lucir_lambda, mu=self.pcfg.thrash_mu,
                )

            (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(params)
            updates, opt_state, _ = self.opt.update(grads, opt_state, params, step)
            params = adamw.apply_updates(params, updates)
            return params, opt_state, metrics

        # n_active is a traced arg (class count grows); use_lucir/use_thrash static
        self._train_step = jax.jit(train_step, static_argnames=("use_lucir", "use_thrash"))

        def eval_step(params, batch, labels, n_active):
            logits, f = self.forward(params, batch)
            lm = jnp.where(jnp.arange(logits.shape[-1]) >= n_active, -1e30, logits)
            return (lm.argmax(-1) == labels), lm.argmax(-1), f

        self._eval_step = jax.jit(eval_step)

    def new_params(self, seed: int = 0):
        return self.init_fn(jax.random.key(seed))

    def evaluate(self, params, fs: FeatureSet, n_active: int):
        """Top-1 correctness per sample + predicted class ids."""
        B = self.tcfg.batch_size
        n = len(fs)
        correct = np.zeros(n, bool)
        pred = np.zeros(n, np.int32)
        for lo in range(0, n, B):
            idx = np.arange(lo, min(lo + B, n))
            pad = B - len(idx)
            pidx = np.concatenate([idx, np.zeros(pad, int)]) if pad else idx
            c, p, _ = self._eval_step(params, _batch_of(fs, pidx), jnp.asarray(fs.label[pidx]), n_active)
            correct[idx] = np.asarray(c)[: len(idx)]
            pred[idx] = np.asarray(p)[: len(idx)]
        return correct, pred

    def old_features(self, prev_params, fs: FeatureSet, idx):
        if prev_params is None:
            return None
        _, _, f = self._eval_step(prev_params, _batch_of(fs, idx), jnp.asarray(fs.label[idx]), 1)
        return f

    def train_group(self, entry: Entry, fs: FeatureSet, n_active: int, *, in_et=None, use_lucir=False, rng=None):
        """Fine-tune on one group (a few epochs)."""
        tc = self.tcfg
        if entry.opt_state is None:
            entry.opt_state = self.opt.init(entry.params)
        n = len(fs)
        if n == 0:
            return entry
        rng = np.random.default_rng(tc.seed if rng is None else rng)
        use_l = use_lucir and entry.prev_params is not None
        dummy_et = jnp.zeros((tc.batch_size,), bool)
        for _ in range(tc.epochs):
            order = rng.permutation(n)
            for lo in range(0, n - tc.batch_size + 1, tc.batch_size):
                idx = order[lo : lo + tc.batch_size]
                f_old = self.old_features(entry.prev_params, fs, idx) if use_l else jnp.zeros((tc.batch_size, self.pcfg.d_model))
                et = jnp.asarray(in_et[idx]) if in_et is not None else dummy_et
                entry.params, entry.opt_state, _ = self._train_step(
                    entry.params, entry.opt_state, _batch_of(fs, idx), jnp.asarray(fs.label[idx]),
                    jnp.asarray(n_active, jnp.int32), entry.step, f_old, et,
                    use_lucir=use_l, use_thrash=in_et is not None,
                )
                entry.step += 1
            if n < tc.batch_size:  # tiny group: single padded batch
                idx = np.resize(order, tc.batch_size)
                f_old = self.old_features(entry.prev_params, fs, idx) if use_l else jnp.zeros((tc.batch_size, self.pcfg.d_model))
                et = jnp.asarray(in_et[idx]) if in_et is not None else dummy_et
                entry.params, entry.opt_state, _ = self._train_step(
                    entry.params, entry.opt_state, _batch_of(fs, idx), jnp.asarray(fs.label[idx]),
                    jnp.asarray(n_active, jnp.int32), entry.step, f_old, et,
                    use_lucir=use_l, use_thrash=in_et is not None,
                )
                entry.step += 1
        entry.n_updates += 1
        return entry


@dataclasses.dataclass
class RunResult:
    top1: float
    per_group: list
    n_classes: int
    n_models: int
    n_samples: int
    predictions: np.ndarray  # predicted class id per sample
    t_index: np.ndarray
    correct: np.ndarray


def run_protocol(
    trace: Trace,
    pcfg: PredictorConfig,
    tcfg: TrainConfig,
    *,
    mode: str = "ours",
    kind: str = "transformer",
    in_et_flags: np.ndarray | None = None,  # per-access E∪T membership (thrash term)
    table: ModelTable | None = None,
) -> RunResult:
    assert mode in ("online_single", "online_multi", "ours", "offline")
    trainer = Trainer(pcfg, tcfg, kind)
    vocab = DeltaVocab(pcfg.delta_vocab)
    stream = FeatureStream(trace, vocab, pcfg.history, page_vocab=pcfg.page_vocab, pc_vocab=pcfg.pc_vocab, tb_vocab=pcfg.tb_vocab)
    classifier = PatternClassifier()

    if mode == "offline":
        fs = stream.windows(0, len(trace))
        n_active = max(vocab.n_classes, 2)
        rng = np.random.default_rng(tcfg.seed)
        train_idx = rng.permutation(len(fs))[: len(fs) // 2]
        entry = Entry(params=trainer.new_params(tcfg.seed))
        sub = fs.slice(0, len(fs))  # full; train on the random half
        half = FeatureSet(*(getattr(fs, f.name)[train_idx] for f in dataclasses.fields(fs)))
        for _ in range(3):  # extra passes — it has future knowledge anyway
            entry = trainer.train_group(entry, half, n_active)
        correct, pred = trainer.evaluate(entry.params, fs, n_active)
        return RunResult(float(correct.mean()), [float(correct.mean())], vocab.n_classes, 1, len(fs), pred, fs.t_index, correct)

    if table is None:
        table = ModelTable(lambda s: trainer.new_params(s), n_slots=tcfg.table_slots)
    multi = mode in ("online_multi", "ours")
    use_lucir = mode == "ours"

    n = len(trace)
    G = tcfg.group_size
    per_group = []
    all_correct = np.zeros(0, bool)
    all_pred = np.zeros(0, np.int32)
    all_t = np.zeros(0, np.int32)
    for g0 in range(0, n, G):
        g1 = min(g0 + G, n)
        fs = stream.windows(g0, g1)
        if len(fs) == 0:
            continue
        n_active = max(vocab.n_classes, 2)
        pat = classifier.classify(trace.block[g0:g1], trace.kernel[g0:g1]) if multi else 0
        entry = table.get(pat)
        correct, pred = trainer.evaluate(entry.params, fs, n_active)  # predict BEFORE training
        per_group.append(float(correct.mean()))
        all_correct = np.concatenate([all_correct, correct])
        all_pred = np.concatenate([all_pred, pred])
        all_t = np.concatenate([all_t, fs.t_index])
        if use_lucir:
            table.snapshot_prev(pat)
            entry = table.get(pat)
        in_et = in_et_flags[fs.t_index] if in_et_flags is not None and mode == "ours" else None
        entry = trainer.train_group(entry, fs, n_active, in_et=in_et, use_lucir=use_lucir)
        table.put(pat, entry)

    top1 = float(all_correct.mean()) if len(all_correct) else 0.0
    return RunResult(top1, per_group, vocab.n_classes, table.n_models, len(all_correct), all_pred, all_t, all_correct)
