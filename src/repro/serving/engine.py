"""Batched serving engine: prefill -> greedy decode with a (dense or paged)
KV cache and an optional KV offload manager driven by attention mass.

Runs real model weights on CPU for the reduced configs; on the production
mesh the same step functions lower via launch/dryrun (decode_32k/long_500k
cells). The offload manager's residency is simulated (we're on CPU) but the
decision stream — hits / misses / prefetches / thrash — is real and is what
the serving benchmarks report.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import lm
from repro.serving.kv_cache import PAGE_TOKENS
from repro.serving.offload import KVOffloadManager, LearnedOffloadManager, LRUOffloadManager

#: offload manager per --offload kind: "lru" (baseline), "learned"
#: (attention-mass EMA driving the paper's policy engine), "manager" (the
#: full streaming OversubscriptionManager — classifier + per-pattern
#: predictor + policy engine on the KV touch stream)
OFFLOAD_KINDS = {"lru": LRUOffloadManager, "learned": KVOffloadManager, "manager": LearnedOffloadManager}


@dataclasses.dataclass
class ServeResult:
    tokens: np.ndarray  # (B, n_new)
    steps: int
    offload_stats: dict | None


class Engine:
    def __init__(self, cfg: ModelConfig, params, *, offload: str | None = None, hbm_fraction: float = 0.5):
        self.cfg = cfg
        self.params = params
        self.prefill = jax.jit(lm.make_prefill(cfg))
        self.decode = jax.jit(lm.make_decode_step(cfg))
        self.offload_kind = offload
        self.hbm_fraction = hbm_fraction

    def generate(self, batch: dict, n_new: int, pad_to: int | None = None) -> ServeResult:
        cfg = self.cfg
        prompt = batch["tokens"]
        B, S = prompt.shape
        total = S + n_new if pad_to is None else pad_to
        # pad the prompt region of the cache to the final length up-front
        pb = dict(batch)
        logits, cache = self.prefill(self.params, pb)
        cache = self._grow_cache(cache, total)

        mgr = None
        if self.offload_kind and cfg.family in ("dense", "moe", "vlm", "encdec"):
            n_pages = (total + PAGE_TOKENS - 1) // PAGE_TOKENS
            cap = max(int(n_pages * self.hbm_fraction), 1)
            mk = OFFLOAD_KINDS.get(self.offload_kind, LRUOffloadManager)
            mgr = mk(n_pages, cap)

        out = np.zeros((B, n_new), np.int32)
        tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
        pos = S
        for i in range(n_new):
            out[:, i] = np.asarray(tok)
            step_batch = {"token": tok, "pos": jnp.asarray(pos, jnp.int32)}
            logits, cache = self.decode(self.params, step_batch, cache)
            if mgr is not None:
                self._drive_offload(mgr, cache, pos)
            tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)
            pos += 1
        return ServeResult(out, n_new, dataclasses.asdict(mgr.stats) if mgr else None)

    def _grow_cache(self, cache, total):
        def grow(a):
            if a.ndim >= 3 and a.shape[2] < total and a.shape[2] > 4:  # (L,B,S,..)
                pad = [(0, 0)] * a.ndim
                pad[2] = (0, total - a.shape[2])
                return jnp.pad(a, pad)
            return a

        keys_seq = {"k", "v"}  # self-attention caches grow; cross/ssm don't
        return {k: (grow(v) if k in keys_seq else v) for k, v in cache.items()}

    def _drive_offload(self, mgr, cache, pos):
        """Approximate per-page attention mass from K-cache recency + norm."""
        k = cache.get("k")
        if k is None:
            return
        n_pages = mgr.n_pages
        valid = min(pos + 1, k.shape[2])
        # mass per token: mean |K| over layers/heads (cheap observable proxy)
        mass_tok = np.asarray(jnp.mean(jnp.abs(k[:, :, :valid].astype(jnp.float32)), axis=(0, 1, 3, 4)))
        mass = np.zeros(n_pages)
        np_full = valid // PAGE_TOKENS
        if np_full:
            mass[:np_full] = mass_tok[: np_full * PAGE_TOKENS].reshape(np_full, PAGE_TOKENS).mean(1)
        rem = valid - np_full * PAGE_TOKENS
        if rem and np_full < n_pages:
            mass[np_full] = mass_tok[np_full * PAGE_TOKENS :].mean()
        # touched pages: pages carrying meaningful attention mass this step.
        # Dense attention with uniform mass touches everything; skewed mass
        # (real prompts / sparse attention) narrows the stall-critical set.
        n_valid_pages = (valid + PAGE_TOKENS - 1) // PAGE_TOKENS
        live = mass[:n_valid_pages]
        thr = 0.5 * live.max() if live.size else 0.0
        touched = np.nonzero(mass >= thr)[0]
        if touched.size == 0:
            touched = np.arange(n_valid_pages)
        mgr.on_attention(mass, touched)
