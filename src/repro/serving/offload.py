"""Learned HBM<->host KV-page offload — the paper's technique at serving time.

TPU-native mapping of the paper's UVM problem (DESIGN.md §2): during
long-context decode the KV cache oversubscribes HBM; cold pages live in host
DRAM and must be prefetched back before attention needs them. This manager
reuses the paper's policy engine verbatim:

  * per decode step, the attention "access stream" is the set of KV pages
    whose attention mass is non-negligible for each sequence;
  * the PREDICTION FREQUENCY TABLE (core.policy) counts predicted page ids —
    here, pages predicted hot by an EMA of attention mass (the serving
    analogue of the delta predictor; a learned predictor plugs into
    `predict_hot` the same way);
  * the PAGE-SET CHAIN partitions pages by recency interval; evictions to
    host pick the lowest-frequency page from the oldest partition;
  * prefetches pull the highest-frequency non-resident pages back to HBM
    ahead of use.

The pool itself is simulated (CPU container): we track residency + move
bytes and surface hit-rates/transfer volumes for the serving benchmarks.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.policy import PredictionFrequencyTable

INTERVAL_STEPS = 64  # chain interval, in decode steps


@dataclasses.dataclass
class OffloadStats:
    hbm_hits: int = 0
    hbm_misses: int = 0  # demand fetch from host (stall!)
    prefetches: int = 0
    evictions: int = 0
    thrash: int = 0  # page evicted then needed again

    @property
    def hit_rate(self) -> float:
        t = self.hbm_hits + self.hbm_misses
        return self.hbm_hits / t if t else 1.0


class KVOffloadManager:
    def __init__(self, n_pages: int, hbm_capacity: int, *, ema: float = 0.8, prefetch_per_step: int = 4):
        self.n_pages = n_pages
        self.capacity = hbm_capacity
        self.resident = np.zeros(n_pages, bool)
        self.evicted_once = np.zeros(n_pages, bool)
        self.last_interval = np.full(n_pages, -1, np.int64)
        self.attn_mass = np.zeros(n_pages, np.float64)  # EMA of attention mass
        self.freq_table = PredictionFrequencyTable()
        self.ema = ema
        self.prefetch_per_step = prefetch_per_step
        self.step = 0
        self.stats = OffloadStats()

    # -- the predictor hook ---------------------------------------------------
    def predict_hot(self, k: int) -> np.ndarray:
        """Pages predicted to be accessed soon (default: attention-mass EMA;
        a learned page predictor can override this)."""
        order = np.argsort(-self.attn_mass)
        return order[:k]

    # -- per decode step --------------------------------------------------------
    def on_attention(self, page_mass: np.ndarray, touched: np.ndarray):
        """page_mass: (n_pages,) attention mass this step; touched: page ids
        the attention actually read."""
        self.attn_mass = self.ema * self.attn_mass + (1 - self.ema) * page_mass
        interval = self.step // INTERVAL_STEPS
        for p in np.asarray(touched, np.int64):
            if self.resident[p]:
                self.stats.hbm_hits += 1
            else:
                self.stats.hbm_misses += 1
                if self.evicted_once[p]:
                    self.stats.thrash += 1
                self._admit(p)
            self.last_interval[p] = interval

        # predictions -> frequency table -> prefetch
        hot = self.predict_hot(4 * self.prefetch_per_step)
        self.freq_table.update(hot)
        if self.step % INTERVAL_STEPS == INTERVAL_STEPS - 1:
            self.freq_table.on_intervals(1)
        for p in hot:
            if not self.resident[p] and self.prefetch_budget > 0:
                self._admit(int(p))
                self.stats.prefetches += 1
        self.step += 1

    @property
    def prefetch_budget(self) -> int:
        return self.prefetch_per_step

    def _admit(self, p: int):
        while self.resident.sum() >= self.capacity:
            self._evict_one(exclude=p)
        self.resident[p] = True

    def _evict_one(self, exclude: int):
        interval = self.step // INTERVAL_STEPS
        age = np.clip(interval - self.last_interval, 0, 2)
        freq = self.freq_table.dense(self.n_pages)
        cand = self.resident.copy()
        cand[exclude] = False
        if not cand.any():
            return
        # oldest partition first, then lowest prediction frequency
        key = (-age * 1_000_000 + freq * 100).astype(np.int64)
        key[~cand] = np.iinfo(np.int64).max
        victim = int(np.argmin(key))
        self.resident[victim] = False
        self.evicted_once[victim] = True
        self.stats.evictions += 1


class LRUOffloadManager(KVOffloadManager):
    """Ablation baseline: plain LRU residency, no prediction."""

    def predict_hot(self, k: int) -> np.ndarray:
        return np.zeros(0, np.int64)

    def _evict_one(self, exclude: int):
        cand = self.resident.copy()
        cand[exclude] = False
        if not cand.any():
            return
        li = self.last_interval.copy()
        li[~cand] = np.iinfo(np.int64).max
        victim = int(np.argmin(li))
        self.resident[victim] = False
        self.evicted_once[victim] = True
        self.stats.evictions += 1
