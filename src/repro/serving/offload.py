"""Learned HBM<->host KV-page offload — the paper's technique at serving time.

TPU-native mapping of the paper's UVM problem (DESIGN.md §2): during
long-context decode the KV cache oversubscribes HBM; cold pages live in host
DRAM and must be prefetched back before attention needs them. Three
managers share one decision-stream surface (:class:`OffloadStats`):

  * :class:`LRUOffloadManager` — plain LRU residency (ablation baseline);
  * :class:`KVOffloadManager` — the paper's policy engine driven by an EMA
    of attention mass (the serving analogue of the delta predictor);
  * :class:`LearnedOffloadManager` — the FULL learned stack: KV-page touch
    streams are adapted into
    :class:`repro.uvm.manager.OversubscriptionManager` observations, so
    the classifier -> per-pattern predictor -> policy engine pipeline that
    drives the trace simulator also decides serving residency (prefetch
    from ``Actions.prefetch_blocks``, eviction from the manager's
    prediction-frequency counters, causal fine-tuning from the hit/miss
    outcomes).

The pool itself is simulated (CPU container): we track residency + move
bytes and surface hit-rates/transfer volumes for the serving benchmarks.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.policy import PredictionFrequencyTable

INTERVAL_STEPS = 64  # chain interval, in decode steps


@dataclasses.dataclass
class OffloadStats:
    hbm_hits: int = 0
    hbm_misses: int = 0  # demand fetch from host (stall!)
    prefetches: int = 0
    evictions: int = 0
    thrash: int = 0  # page evicted then needed again

    @property
    def hit_rate(self) -> float:
        t = self.hbm_hits + self.hbm_misses
        return self.hbm_hits / t if t else 1.0


class KVOffloadManager:
    def __init__(self, n_pages: int, hbm_capacity: int, *, ema: float = 0.8, prefetch_per_step: int = 4):
        self.n_pages = n_pages
        self.capacity = hbm_capacity
        self.resident = np.zeros(n_pages, bool)
        self.evicted_once = np.zeros(n_pages, bool)
        self.last_interval = np.full(n_pages, -1, np.int64)
        self.attn_mass = np.zeros(n_pages, np.float64)  # EMA of attention mass
        self.freq_table = PredictionFrequencyTable()
        self.ema = ema
        self.prefetch_per_step = prefetch_per_step
        self.step = 0
        self.stats = OffloadStats()

    # -- the predictor hook ---------------------------------------------------
    def predict_hot(self, k: int) -> np.ndarray:
        """Pages predicted to be accessed soon (default: attention-mass EMA;
        a learned page predictor can override this)."""
        order = np.argsort(-self.attn_mass)
        return order[:k]

    # -- per decode step --------------------------------------------------------
    def on_attention(self, page_mass: np.ndarray, touched: np.ndarray):
        """page_mass: (n_pages,) attention mass this step; touched: page ids
        the attention actually read."""
        self.attn_mass = self.ema * self.attn_mass + (1 - self.ema) * page_mass
        interval = self.step // INTERVAL_STEPS
        for p in np.asarray(touched, np.int64):
            if self.resident[p]:
                self.stats.hbm_hits += 1
            else:
                self.stats.hbm_misses += 1
                if self.evicted_once[p]:
                    self.stats.thrash += 1
                self._admit(p)
            self.last_interval[p] = interval
            self._note_touch(int(p))
        self._post_step()
        self.step += 1

    def _note_touch(self, p: int):
        """Per-touch hook (the manager adapter buffers its fault batches)."""

    def _post_step(self):
        """End-of-step prediction + prefetch (subclasses replace the source
        of predictions; the default is the attention-mass EMA)."""
        hot = self.predict_hot(4 * self.prefetch_per_step)
        self.freq_table.update(hot)
        if self.step % INTERVAL_STEPS == INTERVAL_STEPS - 1:
            self.freq_table.on_intervals(1)
        for p in hot:
            if not self.resident[p] and self.prefetch_budget > 0:
                self._admit(int(p))
                self.stats.prefetches += 1

    @property
    def prefetch_budget(self) -> int:
        return self.prefetch_per_step

    def _admit(self, p: int):
        while self.resident.sum() >= self.capacity:
            self._evict_one(exclude=p)
        self.resident[p] = True

    def _freq_dense(self) -> np.ndarray:
        """Per-page prediction-frequency counters the eviction key reads."""
        return self.freq_table.dense(self.n_pages)

    def _evict_one(self, exclude: int):
        interval = self.step // INTERVAL_STEPS
        age = np.clip(interval - self.last_interval, 0, 2)
        freq = self._freq_dense()
        cand = self.resident.copy()
        cand[exclude] = False
        if not cand.any():
            return
        # oldest partition first, then lowest prediction frequency
        key = (-age * 1_000_000 + freq * 100).astype(np.int64)
        key[~cand] = np.iinfo(np.int64).max
        victim = int(np.argmin(key))
        self.resident[victim] = False
        self.evicted_once[victim] = True
        self.stats.evictions += 1


class LRUOffloadManager(KVOffloadManager):
    """Ablation baseline: plain LRU residency, no prediction."""

    def predict_hot(self, k: int) -> np.ndarray:
        return np.zeros(0, np.int64)

    def _evict_one(self, exclude: int):
        cand = self.resident.copy()
        cand[exclude] = False
        if not cand.any():
            return
        li = self.last_interval.copy()
        li[~cand] = np.iinfo(np.int64).max
        victim = int(np.argmin(li))
        self.resident[victim] = False
        self.evicted_once[victim] = True
        self.stats.evictions += 1


def _default_serving_manager(n_pages: int, capacity: int, *,
                             reclass_interval: int = 0, reclass_hysteresis: int = 2):
    """A manager sized for KV pages: page == management unit
    (``pages_per_block=1``), a small predictor, single-epoch fine-tuning
    (decode-step batches are tiny).  ``reclass_interval`` opts the ENDLESS
    decode stream into periodic re-classification (hysteresis-guarded)
    instead of classifying every tiny batch; 0 keeps the legacy cadence."""
    from repro.configs.predictor_paper import SMOKE
    from repro.core.incremental import TrainConfig
    from repro.uvm.manager import ManagerConfig, OversubscriptionManager

    cfg = ManagerConfig(
        predictor=SMOKE,
        train=TrainConfig(group_size=64, epochs=1, batch_size=32),
        n_pages=n_pages, n_blocks=n_pages, capacity=capacity,
        pages_per_block=1,
        reclass_interval=reclass_interval, reclass_hysteresis=reclass_hysteresis,
    )
    return OversubscriptionManager(cfg)


class LearnedOffloadManager(KVOffloadManager):
    """KV-page residency decided by the streaming
    :class:`~repro.uvm.manager.OversubscriptionManager` — the same
    classifier/predictor/policy-engine instance that drives the trace
    simulator (pass ``manager=`` to share one; the default builds a fresh
    page-granular manager).

    Adaptation: touched KV pages accumulate into fault batches of
    ``group`` accesses; each full batch becomes one
    ``observe`` -> apply-actions -> ``feedback`` round.  KV page ``p`` is
    observed as page id ``p * pages_per_block``, so the manager's BLOCK id
    is exactly the KV page id whatever granularity its config came with —
    ``Actions.prefetch_blocks`` and the frequency counters are read back
    as KV pages directly.  Prefetches are budgeted like the attention-EMA
    manager, evictions read the manager's counters through the page-set
    chain (oldest partition, lowest frequency), and ``feedback`` carries
    each touch's E∪T membership + the miss count as the fault clock, so
    the predictor fine-tunes causally on the live serving stream.  The
    decision-stream surface (``stats``) is identical to the other
    managers — ``serving.engine`` reports it unchanged.

    ``checkpoint_dir``/``checkpoint_every``/``resume`` survive engine
    restarts: the adapter + manager state snapshots into a
    :class:`~repro.uvm.manager.SnapshotStore` every N observed batches,
    and ``resume=True`` restores the latest snapshot at construction —
    the resumed decision stream is bit-identical to an uninterrupted one
    (same serve-layer invariant as ``cli serve --resume``).
    """

    def __init__(self, n_pages: int, hbm_capacity: int, *, manager=None, group: int = 64,
                 prefetch_per_step: int = 4, reclass_interval: int = 0, reclass_hysteresis: int = 2,
                 checkpoint_dir=None, checkpoint_every: int = 0, resume: bool = False):
        super().__init__(n_pages, hbm_capacity, prefetch_per_step=prefetch_per_step)
        self.manager = manager if manager is not None else _default_serving_manager(
            n_pages, hbm_capacity,
            reclass_interval=reclass_interval, reclass_hysteresis=reclass_hysteresis)
        if self.manager.cfg.n_blocks < n_pages:
            raise ValueError(
                f"manager.cfg.n_blocks ({self.manager.cfg.n_blocks}) must cover the "
                f"KV pool ({n_pages} pages): the manager's block unit is the KV page"
            )
        self.group = group
        self._buf: list[int] = []
        self.last_actions = None
        # engine-restart survival: snapshot the adapter + manager every
        # checkpoint_every observed batches (same store as `cli serve`)
        self._snapshots = None
        self._checkpoint_every = checkpoint_every
        self._observed_batches = 0
        if checkpoint_dir is not None:
            from repro.uvm.manager import SnapshotStore

            self._snapshots = SnapshotStore(checkpoint_dir)
            self._snapshots.clean_tmp()
            if resume and self._snapshots.latest_step() is not None:
                _step, state, _extra = self._snapshots.restore()
                self.restore(state)

    # -- snapshot / restore ---------------------------------------------------

    def state(self) -> dict:
        """Host-side snapshot: the residency adapter's arrays + stats and
        the wrapped manager's full learned state (versioned + config-signed
        by :meth:`OversubscriptionManager.state`)."""
        return {
            "adapter": {
                "resident": self.resident.copy(),
                "evicted_once": self.evicted_once.copy(),
                "last_interval": self.last_interval.copy(),
                "attn_mass": self.attn_mass.copy(),
                "step": self.step,
                "buf": list(self._buf),
                "observed_batches": self._observed_batches,
                "stats": dataclasses.asdict(self.stats),
            },
            "manager": self.manager.state(),
        }

    def restore(self, state: dict) -> None:
        a = state["adapter"]
        self.resident = a["resident"].copy()
        self.evicted_once = a["evicted_once"].copy()
        self.last_interval = a["last_interval"].copy()
        self.attn_mass = a["attn_mass"].copy()
        self.step = a["step"]
        self._buf = list(a["buf"])
        self._observed_batches = a["observed_batches"]
        self.stats = OffloadStats(**a["stats"])
        self.manager.restore(state["manager"])

    # -- the manager adapter --------------------------------------------------

    def _observe_batch(self):
        from repro.uvm.manager import FaultBatch, Outcomes

        batch = np.asarray(self._buf[: self.group], np.int64)
        self._buf = self._buf[self.group:]
        # kv page p -> manager page p*ppb, so manager block id == kv page id
        actions = self.manager.observe(FaultBatch(page=batch * self.manager.cfg.pages_per_block))
        self.last_actions = actions
        budget = self.prefetch_budget
        for p in np.asarray(actions.prefetch_blocks, np.int64):
            if p < self.n_pages and not self.resident[p] and budget > 0:
                self._admit(int(p))
                self.stats.prefetches += 1
                budget -= 1
        # causal fine-tune: E∪T membership of each touch, misses as the
        # fault clock that advances the flush/chain intervals
        self.manager.feedback(Outcomes(
            was_evicted=self.evicted_once[batch],
            fault_count=self.stats.hbm_misses,
        ))
        self._observed_batches += 1
        if (self._snapshots is not None and self._checkpoint_every
                and self._observed_batches % self._checkpoint_every == 0):
            self._snapshots.save(self._observed_batches, self.state())

    def _freq_dense(self) -> np.ndarray:
        # block id == kv page id (see _observe_batch), so the manager's
        # counters index the KV pool directly
        return self.manager.freq_table.dense(self.n_pages)

    def _note_touch(self, p: int):
        self._buf.append(p)

    def _post_step(self):
        while len(self._buf) >= self.group:
            self._observe_batch()
