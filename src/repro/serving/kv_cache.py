"""Paged KV cache: fixed-size token pages + per-sequence block tables.

The pool is the unit of the paper's technique at serving time: pages move
between the HBM pool and host memory under the offload manager
(repro.serving.offload), exactly like 64KB UVM basic blocks move between
device and CPU memory.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

PAGE_TOKENS = 64


@dataclasses.dataclass
class PagedKV:
    """Per-layer pools: k/v (L, n_pages, PAGE_TOKENS, KV, HD)."""

    k: jax.Array
    v: jax.Array
    block_table: np.ndarray  # (B, max_pages) int32 -> pool page id (-1 empty)
    seq_lens: np.ndarray  # (B,)
    free: list[int]

    @classmethod
    def create(cls, n_layers, n_pages, kv_heads, head_dim, batch, max_pages, dtype=jnp.bfloat16):
        shape = (n_layers, n_pages, PAGE_TOKENS, kv_heads, head_dim)
        return cls(
            k=jnp.zeros(shape, dtype),
            v=jnp.zeros(shape, dtype),
            block_table=np.full((batch, max_pages), -1, np.int32),
            seq_lens=np.zeros(batch, np.int32),
            free=list(range(n_pages)),
        )

    def alloc_page(self, seq: int) -> int:
        page = self.free.pop()
        slot = int(self.seq_lens[seq]) // PAGE_TOKENS
        self.block_table[seq, slot] = page
        return page

    def append_token(self, seq: int, layer_k, layer_v):
        """layer_k/v: (L, KV, HD) for one token. Allocates pages on demand."""
        pos = int(self.seq_lens[seq])
        if pos % PAGE_TOKENS == 0:
            self.alloc_page(seq)
        page = int(self.block_table[seq, pos // PAGE_TOKENS])
        off = pos % PAGE_TOKENS
        self.k = self.k.at[:, page, off].set(layer_k)
        self.v = self.v.at[:, page, off].set(layer_v)
        self.seq_lens[seq] = pos + 1

    def gather(self, seq: int, max_len: int):
        """Contiguous (L, max_len, KV, HD) view for the XLA attention path."""
        n_pages = (max_len + PAGE_TOKENS - 1) // PAGE_TOKENS
        pages = self.block_table[seq, :n_pages]
        pages = np.where(pages < 0, 0, pages)
        k = self.k[:, pages].reshape(self.k.shape[0], -1, *self.k.shape[3:])[:, :max_len]
        v = self.v[:, pages].reshape(self.v.shape[0], -1, *self.v.shape[3:])[:, :max_len]
        return k, v

    @property
    def n_pool_pages(self) -> int:
        return self.k.shape[1]
