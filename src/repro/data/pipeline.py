"""Deterministic, shardable, exactly-resumable data pipeline.

Sources:
  * synthetic — seeded Zipfian token stream with injected n-gram structure
    (so models actually reduce loss on it)
  * file      — byte-level tokenisation of a text file, repeated

Determinism contract: batch content is a pure function of (seed, step,
shard), so restarting from a checkpoint at step k reproduces the exact
stream; scaling data-parallel shards re-partitions without replay. Traces for
the UVM predictor flow through the same interface (``TraceBatches``), so the
paper's model trains on the identical substrate.
"""
from __future__ import annotations

import dataclasses
from pathlib import Path

import numpy as np


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    batch: int  # GLOBAL batch
    seq_len: int
    seed: int = 0
    source: str = "synthetic"  # synthetic | file
    path: str = ""
    zipf_a: float = 1.2
    ngram: int = 3


class TokenPipeline:
    """Stateless batch generator: get(step, shard, n_shards) -> (B_shard, S+1)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self._file_tokens: np.ndarray | None = None
        if cfg.source == "file":
            raw = Path(cfg.path).read_bytes()
            self._file_tokens = np.frombuffer(raw, np.uint8).astype(np.int32) % cfg.vocab_size

    def batch_shape(self, n_shards: int = 1) -> tuple[int, int]:
        assert self.cfg.batch % n_shards == 0, "global batch must divide shards"
        return (self.cfg.batch // n_shards, self.cfg.seq_len + 1)

    def get(self, step: int, shard: int = 0, n_shards: int = 1) -> np.ndarray:
        cfg = self.cfg
        bs, width = self.batch_shape(n_shards)
        rows = []
        for i in range(bs):
            global_row = step * cfg.batch + shard * bs + i
            rows.append(self._row(global_row, width))
        return np.stack(rows).astype(np.int32)

    def _row(self, global_row: int, width: int) -> np.ndarray:
        cfg = self.cfg
        if self._file_tokens is not None:
            start = (global_row * width) % max(len(self._file_tokens) - width, 1)
            return self._file_tokens[start : start + width]
        rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, global_row]))
        toks = rng.zipf(cfg.zipf_a, size=width).astype(np.int64) % cfg.vocab_size
        # inject learnable n-gram structure: every n-th token repeats an earlier one
        k = cfg.ngram
        toks[k::k] = toks[: len(toks[k::k])]
        return toks.astype(np.int32)


class TraceBatches:
    """The UVM predictor's view: FeatureSet mini-batches from a trace, with
    the same (seed, step)-deterministic contract."""

    def __init__(self, fs, batch: int, seed: int = 0):
        self.fs = fs
        self.batch = batch
        self.seed = seed

    def get(self, step: int, shard: int = 0, n_shards: int = 1) -> dict[str, np.ndarray]:
        bs = self.batch // n_shards
        rng = np.random.default_rng(np.random.SeedSequence([self.seed, step, shard]))
        idx = rng.integers(0, len(self.fs), bs)
        return {
            "page": self.fs.page[idx],
            "delta": self.fs.delta[idx],
            "pc": self.fs.pc[idx],
            "tb": self.fs.tb[idx],
            "label": self.fs.label[idx],
        }
