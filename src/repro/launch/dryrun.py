import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=" + os.environ.get("REPRO_DRYRUN_DEVICES", "512") + " " + os.environ.get("XLA_FLAGS", "")
"""Multi-pod dry-run: prove the distribution config is coherent without hardware.

For every (architecture x input shape) cell this lowers + compiles the real
step function (train_step / prefill / serve decode_step) against
ShapeDtypeStruct stand-ins on the production mesh — (data=16, model=16)
single pod and (pod=2, data=16, model=16) multi-pod — then records
memory_analysis / cost_analysis / roofline terms to JSON for EXPERIMENTS.md.

The two lines above MUST run before any jax-importing module: jax locks the
device count on first init, and only the dry-run should see 512 placeholder
devices.

Usage:
    python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k
    python -m repro.launch.dryrun --all --mesh single --out experiments/dryrun
    python -m repro.launch.dryrun --all --mesh multi
"""
import argparse
import json
import sys
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, SHAPES, cell_supported, get_config
from repro.distributed import sharding
from repro.launch import mesh as meshmod
from repro.launch import roofline
from repro.models import lm
from repro.models import params as prm
from repro.optim import adamw


def _abstract_tree(specs: dict, dtype=jnp.float32):
    return {p: jax.ShapeDtypeStruct(s.shape, dtype) for p, s in specs.items()}


def _sharding_tree(mesh, specs: dict, rules=None):
    return sharding.params_shardings(mesh, specs, rules)


def build_cell(cfg, shape, mesh, rules=None):
    """Returns (fn, example_args, in_shardings, donate) for jit."""
    pspecs = lm.param_specs(cfg, max_seq=shape.seq_len)
    params_abs = _abstract_tree(pspecs)
    params_sh = _sharding_tree(mesh, pspecs, rules)
    repl = jax.sharding.NamedSharding(mesh, jax.sharding.PartitionSpec())

    bspecs = lm.batch_specs(cfg, shape)
    baxes = lm.batch_axes(cfg, shape)
    batch_sh = {
        k: sharding.named_sharding(mesh, baxes[k], bspecs[k].shape, rules) for k in bspecs
    }

    if shape.kind == "train":
        opt = adamw.adamw(adamw.cosine_schedule(3e-4, 100, 10_000))
        step_fn = lm.make_train_step(cfg, opt)
        opt_abs = adamw.OptState(m=params_abs, v=params_abs)
        opt_sh = adamw.OptState(m=params_sh, v=params_sh)
        args = (params_abs, opt_abs, bspecs, jax.ShapeDtypeStruct((), jnp.int32))
        in_sh = (params_sh, opt_sh, batch_sh, repl)
        donate = (0, 1)
        return step_fn, args, in_sh, donate
    if shape.kind == "prefill":
        fn = lm.make_prefill(cfg)
        args = (params_abs, bspecs)
        in_sh = (params_sh, batch_sh)
        return fn, args, in_sh, ()
    # decode
    cspecs = lm.cache_specs(cfg, shape)
    cache_abs = {p: jax.ShapeDtypeStruct(s.shape, lm.cache_dtype(p, cfg)) for p, s in cspecs.items()}
    cache_sh = _sharding_tree(mesh, cspecs, rules)
    fn = lm.make_decode_step(cfg)
    args = (params_abs, bspecs, cache_abs)
    in_sh = (params_sh, batch_sh, cache_sh)
    return fn, args, in_sh, (2,)


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: Path, *, force=False, rules=None, tag="", kv_quant="none") -> dict:
    cfg = get_config(arch)
    if kv_quant != "none":
        cfg = cfg.replace(kv_quant=kv_quant)
    shape = SHAPES[shape_name]
    out_dir.mkdir(parents=True, exist_ok=True)
    out_path = out_dir / f"{arch}__{shape_name}__{mesh_kind}{tag}.json"
    if out_path.exists() and not force:
        rec = json.loads(out_path.read_text())
        print(f"[cached] {arch} x {shape_name} x {mesh_kind}: {rec.get('status')}")
        return rec

    supported, reason = cell_supported(cfg, shape)
    rec: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_kind, "tag": tag}
    if not supported:
        rec.update(status="skipped", reason=reason)
        out_path.write_text(json.dumps(rec, indent=2))
        print(f"[skip]   {arch} x {shape_name}: {reason}")
        return rec

    mesh = meshmod.make_production_mesh(multi_pod=(mesh_kind == "multi")) if mesh_kind in ("single", "multi") else meshmod.make_mesh(mesh_kind)
    t0 = time.time()
    try:
        fn, args, in_sh, donate = build_cell(cfg, shape, mesh, rules)
        with sharding.use_mesh_rules(mesh, rules):
            lowered = jax.jit(fn, in_shardings=in_sh, donate_argnums=donate).lower(*args)
        t_lower = time.time() - t0
        compiled = lowered.compile()
        t_compile = time.time() - t0 - t_lower

        try:
            mem = compiled.memory_analysis()
            print(mem)
            mem_rec = {
                "temp_bytes": int(getattr(mem, "temp_size_in_bytes", 0)),
                "argument_bytes": int(getattr(mem, "argument_size_in_bytes", 0)),
                "output_bytes": int(getattr(mem, "output_size_in_bytes", 0)),
                "alias_bytes": int(getattr(mem, "alias_size_in_bytes", 0)),
                "generated_code_bytes": int(getattr(mem, "generated_code_size_in_bytes", 0)),
            }
        except Exception as e:  # CPU backend may not implement it
            mem_rec = {"error": str(e)}

        ca = compiled.cost_analysis() or {}
        if isinstance(ca, (list, tuple)):  # jax 0.4.x returns one dict per device
            ca = ca[0] if ca else {}
        cost = dict(ca)
        print({k: cost[k] for k in ("flops", "bytes accessed") if k in cost})

        tokens = shape.global_batch * (shape.seq_len if shape.kind != "decode" else 1)
        n_active = lm.active_param_count(cfg, max_seq=shape.seq_len)
        factor = 6 if shape.kind == "train" else 2
        model_flops = factor * n_active * tokens

        hlo = compiled.as_text()
        from repro.models.layers import ATTN_KV_CHUNK

        rl = roofline.analyze(compiled, mesh, model_flops, hlo_text=hlo, attn_score_trailing=ATTN_KV_CHUNK)
        rec.update(
            status="ok",
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            params=lm.param_count(cfg, max_seq=shape.seq_len),
            active_params=n_active,
            tokens_per_step=tokens,
            memory_analysis=mem_rec,
            cost_flops=cost.get("flops", 0.0),
            cost_bytes=cost.get("bytes accessed", 0.0),
            roofline=rl.table_row(),
            collectives=rl.coll.bytes_by_kind,
            top_traffic=[
                {"bytes": b, "mult": m, "op": o, "shape": s} for b, m, o, s in rl.top_traffic
            ],
            hlo_bytes_len=len(hlo),
        )
        print(
            f"[ok]     {arch} x {shape_name} x {mesh_kind}{tag}: "
            f"compute={rl.compute_s:.4e}s memory={rl.memory_s:.4e}s "
            f"collective={rl.collective_s:.4e}s bottleneck={rl.bottleneck} "
            f"useful={rl.useful_ratio:.2f} (compile {t_compile:.1f}s)"
        )
    except Exception:
        rec.update(status="error", error=traceback.format_exc())
        print(f"[ERROR]  {arch} x {shape_name} x {mesh_kind}{tag}:\n{rec['error']}", file=sys.stderr)
    out_path.write_text(json.dumps(rec, indent=2))
    return rec


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", choices=ARCHS + ["all"], default="all")
    ap.add_argument("--shape", choices=list(SHAPES) + ["all"], default="all")
    ap.add_argument("--mesh", default="single", help="single | multi | WxH | pod:PxWxH")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--rules", choices=["default", "fsdp_only"], default="default")
    ap.add_argument("--tag", default="", help="suffix for experiment records (hillclimb variants)")
    ap.add_argument("--kv-quant", choices=["none", "int8"], default="none")
    args = ap.parse_args(argv)

    rules = sharding.FSDP_ONLY_RULES if args.rules == "fsdp_only" else None
    archs = ARCHS if args.arch == "all" else [args.arch]
    shapes = list(SHAPES) if args.shape == "all" else [args.shape]
    out_dir = Path(args.out)

    n_err = 0
    for arch in archs:
        for shape_name in shapes:
            rec = run_cell(arch, shape_name, args.mesh, out_dir, force=args.force, rules=rules, tag=args.tag, kv_quant=args.kv_quant)
            n_err += rec.get("status") == "error"
    print(f"done; {n_err} errors")
    return 1 if n_err else 0


if __name__ == "__main__":
    raise SystemExit(main())
