"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never touches
jax device state, so smoke tests see 1 device while the dry-run (which sets
``--xla_force_host_platform_device_count=512`` before any import) sees the
full placeholder fleet.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(spec: str):
    """Parse e.g. '16x16' / 'pod:2x16x16' / '4x2' into a mesh (small-mesh tests)."""
    if spec.startswith("pod:"):
        dims = tuple(int(x) for x in spec[4:].split("x"))
        axes = ("pod", "data", "model")[-len(dims):]
    else:
        dims = tuple(int(x) for x in spec.split("x"))
        axes = ("data", "model")[-len(dims):]
    return jax.make_mesh(dims, axes)


# TPU v5e hardware constants (roofline targets; the container runs on CPU).
PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link
ICI_LINKS = 4  # 2D torus: 4 links/chip (v5e)
DCI_BW = 25e9  # bytes/s per chip across pods (optics), used for the pod axis
HBM_PER_CHIP = 16 * 2**30
