"""Roofline-term derivation from a compiled dry-run artifact.

Per (arch x shape x mesh):
    compute    = HLO_FLOPs / (chips * PEAK_FLOPS)
    memory     = HLO_bytes / (chips * HBM_BW)
    collective = collective_bytes / (chips * link_BW * links)

``compiled.cost_analysis()`` counts a `while` body ONCE, so scan-over-layers
programs undercount by ~num_layers. We therefore parse the optimized HLO into
a computation graph, recover loop trip counts from each while-condition's
`s32[] constant(K)`, propagate a multiplier through `body=` / `calls=` /
`to_apply=` edges, and then:

  * FLOPs  — sum 2 * out_elems * contracted_elems over every `dot`, scaled.
  * bytes  — static traffic model: for every op in a *memory-level*
    computation (ENTRY, while bodies/conds, conditional branches — NOT inside
    fusion bodies) sum output + operand bytes, scaled. Fusions count at their
    call site, so fused elementwise chains count once. This over-approximates
    post-fusion HBM traffic slightly but is consistent across variants, which
    is what the perf hillclimb needs.
  * collective bytes — operand bytes of all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute, scaled; split ICI vs
    DCI by whether the replica group crosses a 256-chip pod boundary.

Everything is per *program*; divide by chips for per-chip seconds.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

import numpy as np

from repro.launch import mesh as meshmod

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "token": 0,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute")
# Ops counted by the static HBM-traffic model. Raw elementwise / broadcast /
# convert ops are EXCLUDED: on TPU they fuse into neighbours (the CPU backend
# leaves them at top level, which would overstate traffic ~10x). `fusion` ops
# count their operands+outputs once, which is exactly the fused-kernel model.
_TRAFFIC_OPS = {
    "fusion", "dot", "convolution", "copy", "transpose", "reduce",
    "reduce-window", "dynamic-slice", "dynamic-update-slice", "slice",
    "concatenate", "pad", "reverse", "gather", "scatter", "sort",
    "select-and-scatter", "all-gather", "all-reduce", "reduce-scatter",
    "all-to-all", "collective-permute",
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OPLINE_RE = re.compile(r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.*)$")
_HEAD_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s*\(")


def shape_elems(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        if m.group(1) not in _DTYPE_BYTES:
            continue
        n = 1
        if m.group(2):
            for d in m.group(2).split(","):
                n *= int(d)
        total += n
    return total


def shape_bytes(shape_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(shape_str):
        dt = m.group(1)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if m.group(2):
            for d in m.group(2).split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


@dataclasses.dataclass
class Op:
    name: str
    out: str       # output type string (may be a tuple)
    opcode: str
    operands: list[str]
    line: str


@dataclasses.dataclass
class Computation:
    name: str
    ops: dict[str, Op]
    is_entry: bool = False


def parse_hlo(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for raw in text.splitlines():
        if not raw.strip():
            continue
        if not raw.startswith(" ") and raw.rstrip().endswith("{"):
            m = _HEAD_RE.match(raw.strip())
            if m:
                cur = Computation(m.group(2), {}, is_entry=bool(m.group(1)))
                comps[cur.name] = cur
            continue
        if raw.strip() == "}":
            cur = None
            continue
        if cur is None:
            continue
        m = _OPLINE_RE.match(raw)
        if not m:
            continue
        name, rest = m.group(1), m.group(2)
        # rest = TYPE opcode(args...), attrs...   TYPE may be a (tuple, type)
        # possibly containing /*index=N*/ comments — scan balanced parens.
        if rest.startswith("("):
            depth, j = 1, 1
            while j < len(rest) and depth:
                if rest[j] == "(":
                    depth += 1
                elif rest[j] == ")":
                    depth -= 1
                j += 1
            out_t = rest[:j]
            tail = rest[j:].lstrip()
        else:
            sp = rest.find(" ")
            if sp < 0:
                continue
            out_t = rest[:sp]
            tail = rest[sp + 1 :].lstrip()
        om = re.match(r"([\w\-]+)\(", tail)
        if not om:
            continue
        opcode = om.group(1)
        rest = tail
        # operand list: up to matching close paren
        start = om.end()
        depth = 1
        i = start
        while i < len(rest) and depth:
            if rest[i] == "(":
                depth += 1
            elif rest[i] == ")":
                depth -= 1
            i += 1
        arg_str = rest[start : i - 1]
        operands = re.findall(r"%([\w\.\-]+)", arg_str)
        cur.ops[name] = Op(name, out_t, opcode, operands, rest)
    return comps


def _trip_count(cond: Computation) -> int:
    best = 0
    for op in cond.ops.values():
        if op.opcode == "constant" and op.out in ("s32[]", "u32[]", "s64[]", "u64[]"):
            m = re.search(r"constant\((\d+)\)", op.line)
            if m:
                best = max(best, int(m.group(1)))
    return max(best, 1)


_CALL_ATTRS = ("calls", "to_apply", "body", "condition")


def _callees(op: Op) -> list[tuple[str, str]]:
    out = []
    for attr in _CALL_ATTRS:
        for m in re.finditer(rf"{attr}=%?([\w\.\-]+)", op.line):
            out.append((attr, m.group(1)))
    m = re.search(r"branch_computations=\{([^}]*)\}", op.line)
    if m:
        for name in re.findall(r"%?([\w\.\-]+)", m.group(1)):
            out.append(("branch", name))
    return out


def analyze_hlo(text: str, attn_score_trailing: int | None = None):
    comps = parse_hlo(text)
    entry = next((c for c in comps.values() if c.is_entry), None)
    if entry is None:
        raise ValueError("no ENTRY computation found")

    # scale multipliers + fused/memory-level marking (fused=False dominates)
    state: dict[str, tuple[int, bool]] = {}

    def visit(cname: str, mult: int, is_fused: bool):
        prev = state.get(cname, (0, True))
        new = (max(prev[0], mult), prev[1] and is_fused)
        if new == prev:
            return
        state[cname] = new
        comp = comps.get(cname)
        if comp is None:
            return
        for op in comp.ops.values():
            callees = _callees(op)
            trip = 1
            if op.opcode == "while":
                cond_name = next((n for a, n in callees if a == "condition"), None)
                if cond_name and cond_name in comps:
                    trip = _trip_count(comps[cond_name])
            for attr, callee in callees:
                if attr == "body":
                    visit(callee, new[0] * trip, is_fused)
                elif attr == "condition":
                    visit(callee, new[0] * (trip + 1), is_fused)
                elif attr in ("calls", "to_apply"):
                    visit(callee, new[0], True)
                else:  # branch
                    visit(callee, new[0], is_fused)

    visit(entry.name, 1, False)
    scale = {k: v[0] for k, v in state.items()}
    fused = {k: v[1] for k, v in state.items()}

    flops = 0.0
    mem_bytes = 0.0
    attn_score_bytes = 0.0  # traffic a flash-attention kernel keeps in VMEM
    top_traffic: list[tuple[float, int, str, str]] = []
    coll_by_kind: dict[str, float] = defaultdict(float)
    coll_lines: list[tuple[str, int, float]] = []  # (line, scale, bytes)

    def is_score_shaped(shape_str: str) -> bool:
        if attn_score_trailing is None:
            return False
        m = _SHAPE_RE.search(shape_str)
        if not m or not m.group(2):
            return False
        dims = [int(x) for x in m.group(2).split(",")]
        return (
            len(dims) >= 4
            and dims[-1] == attn_score_trailing
            and int(np.prod(dims)) >= 1 << 22
        )

    for cname, comp in comps.items():
        mult = scale.get(cname, 0)
        if mult == 0:
            continue
        symtab = {op.name: op.out for op in comp.ops.values()}
        memory_level = not fused.get(cname, True)
        for op in comp.ops.values():
            # FLOPs from dots (counted wherever they live, incl. fusion bodies)
            if op.opcode == "dot":
                out_e = shape_elems(op.out)
                k = 1
                md = re.search(r"lhs_contracting_dims=\{([\d,]+)\}", op.line)
                if md and op.operands:
                    lhs_t = symtab.get(op.operands[0], "")
                    sm = _SHAPE_RE.search(lhs_t)
                    if sm and sm.group(2):
                        dims = [int(x) for x in sm.group(2).split(",")]
                        bdims = re.search(r"lhs_batch_dims=\{([\d,]*)\}", op.line)
                        for ci in md.group(1).split(","):
                            ci = int(ci)
                            if ci < len(dims):
                                k *= dims[ci]
                flops += 2.0 * out_e * k * mult
            elif op.opcode == "convolution":
                # rough: 2 * out_elems * (kernel elems / out-channel)
                out_e = shape_elems(op.out)
                kern = shape_elems(symtab.get(op.operands[1], "")) if len(op.operands) > 1 else 1
                flops += 2.0 * out_e * max(kern, 1) ** 0.5 * mult  # loose lower bound

            # memory traffic at memory level
            if memory_level and op.opcode in _TRAFFIC_OPS:
                b = shape_bytes(op.out)
                score = is_score_shaped(op.out)
                for o in op.operands:
                    ot = symtab.get(o, "")
                    b += shape_bytes(ot)
                    score = score or is_score_shaped(ot)
                # In-place updates: a dynamic-update-slice (or a fusion rooted
                # in one) aliases its big operand on TPU (donation / while
                # carry); real traffic is the written slice, twice (read+write),
                # plus the small operands — not the whole buffer.
                dus_update = None
                if op.opcode == "dynamic-update-slice" and op.operands:
                    dus_update = symtab.get(op.operands[1], "")
                elif op.opcode == "fusion":
                    m = re.search(r"calls=%?([\w\.\-]+)", op.line)
                    callee = comps.get(m.group(1)) if m else None
                    if callee is not None:
                        for cop in callee.ops.values():
                            # dtype converts may wrap the DUS — match on elems
                            if cop.opcode == "dynamic-update-slice" and shape_elems(cop.out) == shape_elems(op.out):
                                csym = {o2.name: o2.out for o2 in callee.ops.values()}
                                dus_update = csym.get(cop.operands[1], "") if len(cop.operands) > 1 else ""
                                break
                if dus_update is not None and shape_elems(dus_update) < shape_elems(op.out):
                    big = shape_bytes(op.out)
                    slice_b = int(big * shape_elems(dus_update) / max(shape_elems(op.out), 1))
                    b = 2 * slice_b + max(b - 2 * big, 0)
                mem_bytes += b * mult
                if score:
                    attn_score_bytes += b * mult
                top_traffic.append((b * mult, mult, op.opcode, op.out[:64]))

            # collectives
            if op.opcode in _COLLECTIVES or any(op.opcode.startswith(c) for c in _COLLECTIVES):
                kind = next(c for c in _COLLECTIVES if op.opcode.startswith(c))
                opb = sum(shape_bytes(symtab.get(o, "")) for o in op.operands)
                if opb == 0:
                    opb = shape_bytes(op.out)
                coll_by_kind[kind] += opb * mult
                coll_lines.append((op.line, mult, opb))

    top_traffic.sort(key=lambda t: -t[0])
    return {
        "flops": flops,
        "mem_bytes": mem_bytes,
        "attn_score_bytes": attn_score_bytes,
        "top_traffic": top_traffic[:20],
        "coll_by_kind": dict(coll_by_kind),
        "coll_lines": coll_lines,
        "n_computations": len(comps),
    }


def _group_crosses_pod(line: str, per_pod: int) -> bool | None:
    """True/False if determinable from replica_groups, else None."""
    m = re.search(r"replica_groups=\{\{([^}]+)\}", line)
    if m:
        ids = [int(x) for x in m.group(1).split(",") if x.strip().isdigit()]
        return len({i // per_pod for i in ids}) > 1
    m = re.search(r"replica_groups=\[(\d+),(\d+)\]<=\[([\d,]+)\](T\(([\d,]+)\))?", line)
    if m:
        g, s, src = int(m.group(1)), int(m.group(2)), [int(x) for x in m.group(3).split(",")]
        ids = np.arange(int(np.prod(src)))
        if m.group(5):
            perm = [int(x) for x in m.group(5).split(",")]
            ids = ids.reshape(src).transpose(perm).reshape(-1)
        groups = ids.reshape(g, s)
        return bool((np.ptp(groups // per_pod, axis=1) > 0).any())
    return None


@dataclasses.dataclass
class CollectiveStats:
    bytes_by_kind: dict
    ici_bytes: float
    dci_bytes: float


@dataclasses.dataclass
class Roofline:
    chips: int
    hlo_flops: float       # cost_analysis raw (while bodies once)
    scaled_flops: float    # HLO-parsed, while-scaled
    hlo_bytes: float
    scaled_bytes: float
    coll: CollectiveStats
    model_flops: float
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    useful_ratio: float
    per_device_peak_bytes: int
    attn_score_bytes: float = 0.0
    top_traffic: list = dataclasses.field(default_factory=list)

    def table_row(self) -> dict:
        return {
            "chips": self.chips,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "hlo_flops": self.scaled_flops,
            "hlo_bytes": self.scaled_bytes,
            "useful_ratio": self.useful_ratio,
            "coll_ici_bytes": self.coll.ici_bytes,
            "coll_dci_bytes": self.coll.dci_bytes,
            "peak_bytes_per_dev": self.per_device_peak_bytes,
            "attn_score_bytes": self.attn_score_bytes,
        }


def analyze(compiled, mesh, model_flops: float, hlo_text: str | None = None, attn_score_trailing: int | None = None) -> Roofline:
    chips = int(np.prod(list(mesh.shape.values())))
    pod = mesh.shape.get("pod", 1)
    per_pod = chips // pod if pod > 1 else chips
    hlo = hlo_text if hlo_text is not None else compiled.as_text()
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, (list, tuple)):  # jax 0.4.x returns one dict per device
        ca = ca[0] if ca else {}
    cost = dict(ca)
    parsed = analyze_hlo(hlo, attn_score_trailing=attn_score_trailing)

    ici = dci = 0.0
    for line, mult, opb in parsed["coll_lines"]:
        crosses = _group_crosses_pod(line, per_pod) if pod > 1 else False
        if crosses:
            dci += opb * mult
        else:
            ici += opb * mult
    coll = CollectiveStats(parsed["coll_by_kind"], ici, dci)

    scaled_flops = max(parsed["flops"], float(cost.get("flops", 0.0)))
    scaled_bytes = max(parsed["mem_bytes"], float(cost.get("bytes accessed", 0.0)))

    # NOTE: the compiled SPMD module's shapes are PER-DEVICE (post-partition),
    # so parsed FLOPs/bytes are already per-chip — no further division.
    compute_s = scaled_flops / meshmod.PEAK_FLOPS_BF16
    memory_s = scaled_bytes / meshmod.HBM_BW
    ici_s = ici / (meshmod.ICI_BW * meshmod.ICI_LINKS)
    dci_s = dci / meshmod.DCI_BW
    collective_s = ici_s + dci_s

    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    bottleneck = max(terms, key=terms.get)

    peak = 0
    try:
        ma = compiled.memory_analysis()
        peak = int(
            getattr(ma, "temp_size_in_bytes", 0)
            + getattr(ma, "argument_size_in_bytes", 0)
            + getattr(ma, "output_size_in_bytes", 0)
            - getattr(ma, "alias_size_in_bytes", 0)
        )
    except Exception:
        pass

    return Roofline(
        chips=chips,
        hlo_flops=float(cost.get("flops", 0.0)),
        scaled_flops=scaled_flops,
        hlo_bytes=float(cost.get("bytes accessed", 0.0)),
        scaled_bytes=scaled_bytes,
        coll=coll,
        model_flops=model_flops,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        bottleneck=bottleneck,
        # model_flops is global; parsed flops are per-chip
        useful_ratio=((model_flops / chips) / scaled_flops) if scaled_flops else 0.0,
        per_device_peak_bytes=peak,
        attn_score_bytes=parsed["attn_score_bytes"],
        top_traffic=parsed["top_traffic"],
    )
