"""Config-driven distributed trainer.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2-0.5b --smoke \
        --steps 50 --batch 8 --seq 128 --out /tmp/run1

Features: sharded jit train step (resolver shardings), gradient accumulation,
deterministic resumable data, atomic checkpointing + crash recovery,
straggler-policy gradient renormalisation, optional int8 pod-axis gradient
compression, elastic mesh planning from whatever devices exist.

The same entry point trains the PAPER'S PREDICTOR at fleet scale:
    python -m repro.launch.train --arch predictor-paper --steps 200
(its data pipeline is the UVM trace corpus instead of the token stream).
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.configs import get_config, get_smoke_config
from repro.data.pipeline import DataConfig, TokenPipeline
from repro.distributed import sharding
from repro.distributed.elastic import ElasticController, StragglerPolicy
from repro.launch import mesh as meshmod
from repro.models import lm
from repro.optim import adamw


def make_mesh_from_devices(prefer_model: int = 1):
    n = len(jax.devices())
    ctl = ElasticController(n, prefer_model=prefer_model)
    pod, data, model = ctl.mesh_shape
    dims, axes = [], []
    for d, a in zip((pod, data, model), ("pod", "data", "model")):
        if d > 1 or a == "data":
            dims.append(d)
            axes.append(a)
    return jax.make_mesh(tuple(dims), tuple(axes)), ctl


def train_lm(args) -> dict:
    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mesh, ctl = make_mesh_from_devices(prefer_model=args.tp)
    n_shards = ctl.data_shards

    dcfg = DataConfig(vocab_size=cfg.vocab_size, batch=args.batch, seq_len=args.seq, seed=args.seed)
    pipe = TokenPipeline(dcfg)
    opt = adamw.adamw(adamw.cosine_schedule(args.lr, args.warmup, args.steps), weight_decay=0.1)
    accum = max(args.accum, 1)
    straggler = StragglerPolicy(n_microbatches=accum)

    specs = lm.param_specs(cfg, max_seq=args.seq)
    params_sh = sharding.params_shardings(mesh, specs)
    rng = jax.random.key(args.seed)
    with sharding.use_mesh_rules(mesh):
        params = jax.jit(lambda r: lm.init(r, cfg, max_seq=args.seq), out_shardings=params_sh)(rng)
        opt_state = jax.jit(opt.init, out_shardings=adamw.OptState(m=params_sh, v=params_sh))(params)

    grad_step = jax.jit(lm.make_grad_step(cfg))
    apply_fn = jax.jit(
        lambda p, o, g, s: _apply(opt, p, o, g, s),
        donate_argnums=(0, 1),
    )

    ckpt = Checkpointer(args.out, keep=3)
    ckpt.clean_tmp()
    start = 0
    if args.resume and ckpt.latest_step() is not None:
        start, tree, extra = ckpt.restore(shardings=params_sh)
        params = {k: v for k, v in tree.items() if not k.startswith("opt/")}
        m = {k[len("opt/m/"):]: v for k, v in tree.items() if k.startswith("opt/m/")}
        v = {k[len("opt/v/"):]: v for k, v in tree.items() if k.startswith("opt/v/")}
        if m:
            opt_state = adamw.OptState(m=m, v=v)
        print(f"resumed from step {start}")

    log = []
    t0 = time.time()
    with sharding.use_mesh_rules(mesh):
        for step in range(start, args.steps):
            grads = None
            landed = 0
            for micro in range(accum):
                batch_np = pipe.get(step * accum + micro)
                batch = {"tokens": jnp.asarray(batch_np)}
                g, metrics = grad_step(params, batch)
                grads = g if grads is None else jax.tree.map(jnp.add, grads, g)
                landed += 1
                if args.simulate_straggler_drop and micro == accum - 1 and step % 7 == 3:
                    landed -= 1  # deadline missed: drop the last microbatch
                    grads = jax.tree.map(lambda a, b: a - b, grads, g)
            grads, ok = straggler.combine(grads, max(landed, 1))
            grads = jax.tree.map(lambda g_: g_ / max(landed, 1), grads)
            params, opt_state = apply_fn(params, opt_state, grads, step)
            if step % args.log_every == 0 or step == args.steps - 1:
                rec = {"step": step, "loss": float(metrics["total_loss"]), "t": round(time.time() - t0, 1)}
                log.append(rec)
                print(rec)
            if args.ckpt_every and (step + 1) % args.ckpt_every == 0:
                tree = dict(params)
                tree.update({f"opt/m/{k}": v for k, v in opt_state.m.items()})
                tree.update({f"opt/v/{k}": v for k, v in opt_state.v.items()})
                ckpt.save(step + 1, tree, extra={"arch": cfg.name})
    return {"final_loss": log[-1]["loss"] if log else None, "log": log, "mesh": ctl.mesh_shape}


def _apply(opt, params, opt_state, grads, step):
    updates, opt_state, _ = opt.update(grads, opt_state, params, step)
    return adamw.apply_updates(params, updates), opt_state


def train_predictor(args) -> dict:
    """Fleet-scale training of the paper's predictor on a trace corpus."""
    from repro.configs.predictor_paper import CONFIG, SMOKE
    from repro.core.features import DeltaVocab, FeatureStream
    from repro.core.incremental import TrainConfig, Trainer
    from repro.uvm.trace import BENCHMARKS

    pcfg = SMOKE if args.smoke else CONFIG
    tcfg = TrainConfig(batch_size=args.batch, lr=args.lr, epochs=1)
    trainer = Trainer(pcfg, tcfg, kind="transformer")
    corpus = [fn(scale=0.25, seed=100 + i) for i, fn in enumerate(BENCHMARKS.values())]
    from repro.core.model_table import Entry

    entry = Entry(params=trainer.new_params(args.seed))
    losses = []
    for step in range(args.steps):
        tr = corpus[step % len(corpus)]
        vocab = DeltaVocab(pcfg.delta_vocab)
        stream = FeatureStream(tr, vocab, pcfg.history, page_vocab=pcfg.page_vocab, pc_vocab=pcfg.pc_vocab, tb_vocab=pcfg.tb_vocab)
        fs = stream.windows(0, min(len(tr), 2048))
        entry = trainer.train_group(entry, fs, max(vocab.n_classes, 2))
        corr, _ = trainer.evaluate(entry.params, fs, max(vocab.n_classes, 2))
        losses.append(float(corr.mean()))
        if step % args.log_every == 0:
            print({"step": step, "train_top1": losses[-1]})
    return {"final_top1": losses[-1], "log": losses}


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true", help="use the reduced config")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--warmup", type=int, default=10)
    ap.add_argument("--tp", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="/tmp/repro_train")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=5)
    ap.add_argument("--simulate-straggler-drop", action="store_true")
    args = ap.parse_args(argv)
    if args.arch == "predictor-paper":
        out = train_predictor(args)
    else:
        out = train_lm(args)
    print(json.dumps({k: v for k, v in out.items() if k != "log"}))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
