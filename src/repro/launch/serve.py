"""Batched serving driver with the paper's learned KV-offload manager.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-0.5b --smoke \
        --batch 4 --prompt-len 64 --new-tokens 32 --offload learned
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp

from repro.configs import get_config, get_smoke_config
from repro.models import lm
from repro.serving.engine import Engine


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--arch", default="qwen2-0.5b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--new-tokens", type=int, default=32)
    ap.add_argument("--offload", choices=["none", "lru", "learned", "manager"], default="none")
    ap.add_argument("--hbm-fraction", type=float, default=0.5)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    total = args.prompt_len + args.new_tokens
    params = lm.init(jax.random.key(args.seed), cfg, max_seq=total)
    rng = jax.random.key(args.seed + 1)
    batch = {"tokens": jax.random.randint(rng, (args.batch, args.prompt_len), 0, cfg.vocab_size, jnp.int32)}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(rng, (args.batch, cfg.enc_len, cfg.enc_feat), jnp.float32).astype(jnp.bfloat16)
    if cfg.family == "vlm":
        batch["patches"] = jax.random.normal(rng, (args.batch, cfg.num_patches, cfg.patch_feat), jnp.float32).astype(jnp.bfloat16)

    eng = Engine(cfg, params, offload=None if args.offload == "none" else args.offload, hbm_fraction=args.hbm_fraction)
    res = eng.generate(batch, args.new_tokens, pad_to=total)
    out = {
        "arch": cfg.name,
        "generated_shape": list(res.tokens.shape),
        "first_seq": res.tokens[0, :8].tolist(),
        "offload": res.offload_stats,
    }
    print(json.dumps(out, indent=2))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
