"""Simulator hot-path throughput benchmark (ISSUEs 1 + 2 + 4).

Measures, per suite benchmark:
  * cold (compile-inclusive) and warm single-cell wall clock + accesses/sec
  * a 16-cell vmapped policy/prefetch/oversubscription sweep (run_batch)
    wall clock + aggregate cell-accesses/sec
  * the event-compression ratio actually achieved on the trace, for both
    plain run-length (`compress_rle_x`) and period-p interleave-aware
    compression (`compress_x`, what run/run_batch actually use)

    PYTHONPATH=src python -m benchmarks.sim_perf            # full quick-scale sweep
    PYTHONPATH=src python -m benchmarks.sim_perf --smoke    # CI: 3 benchmarks + concurrent + sharded lane
    PYTHONPATH=src python -m benchmarks.sim_perf --manager  # manager section: vectorized vs loop freq table
    PYTHONPATH=src python -m benchmarks.sim_perf --kernels  # kernels section: Pallas vs scan/host paths
    PYTHONPATH=src python -m benchmarks.sim_perf --update-baseline  # rewrite BENCH_sim.json "after"

``--manager`` prepends the streaming-manager section to the requested
run: the vectorized `PredictionFrequencyTable.update/dense` against the
frozen per-block loop (`LoopPredictionFrequencyTable`) on real benchmark
block streams, asserting identical table state and a real speedup;
combined with ``--update-baseline`` it records before/after into
BENCH_sim.json under ``manager``.

``--kernels`` prepends the Pallas-kernel section (ISSUE 10): the
victim-selection kernel path (``REPRO_SIM_KERNELS=1``) against the
default scan path over the full sweep grid — counters must stay
bit-identical (hard gate) — plus `PallasPredictionFrequencyTable`
against the host table on the same block streams.  On CPU hosts the
kernels run in interpret mode, so the ratio gate is a regression bound;
compiled-backend numbers are recorded into BENCH_sim.json as pending.

Output: experiments/bench/sim_perf.csv (+ the `name,us_per_call,derived`
contract line) and a comparison against the committed BENCH_sim.json
baseline so later PRs can track the trajectory.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

import numpy as np

from benchmarks.common import emit
from repro.uvm import simulator as S
from repro.uvm import trace as T
from repro.uvm.sweeps import EQUIV_CELLS as SWEEP_CELLS
from repro.uvm.sweeps import run_batch_forced_devices

BASELINE_PATH = Path(__file__).resolve().parent.parent / "BENCH_sim.json"


def bench_one(tr: T.Trace, name: str | None = None) -> dict:
    name = name or tr.name
    n = len(tr)
    blocks = tr.block.astype(np.int32)
    ev_rle = S.compress_events(blocks, S.next_use_for(tr))
    ev = S.compress_events(blocks, S.next_use_for(tr), periodic=True)

    t0 = time.time()
    S.run(tr, policy="lru", prefetch="tree")
    cold_s = time.time() - t0

    t0 = time.time()
    reps = 3
    for _ in range(reps):
        S.run(tr, policy="lru", prefetch="tree")
    warm_s = (time.time() - t0) / reps

    t0 = time.time()
    S.run_batch(tr, SWEEP_CELLS)
    sweep_s = time.time() - t0

    return {
        "benchmark": name,
        "accesses": n,
        "events": len(ev.blk),
        "events_rle": len(ev_rle.blk),
        "compress_x": round(n / max(len(ev.blk), 1), 2),
        "compress_rle_x": round(n / max(len(ev_rle.blk), 1), 2),
        "cold_s": round(cold_s, 3),
        "warm_s": round(warm_s, 4),
        "warm_acc_per_s": int(n / max(warm_s, 1e-9)),
        "sweep16_s": round(sweep_s, 3),
        "sweep_cell_acc_per_s": int(len(SWEEP_CELLS) * n / max(sweep_s, 1e-9)),
    }


def _suite_trace(name: str, scale: float, cap: int) -> T.Trace:
    tr = T.get_trace(name, scale=scale)
    return tr.slice(0, min(len(tr), cap))


def _sharded_lane_check(scale: float, cap: int) -> None:
    """Run one run_batch sweep in a subprocess with 4 forced host devices:
    the lane-sharded path must produce the same counters as this process's
    (single-device) run. Counters are integer state — bit-equality holds."""
    want = S.run_batch(_suite_trace("ATAX", scale, cap), SWEEP_CELLS)
    got = run_batch_forced_devices("ATAX", scale, cap)
    assert got == want, "sharded run_batch diverged from single-device counters"
    print("# sharded lane ok (4 host devices, counters bit-identical)")


def bench_manager(scale: float, cap: int) -> list[dict]:
    """The `--manager` section: vectorized vs loop frequency-table engine
    on real block streams (update + dense export per group, flush cadence
    every 3 groups), table state asserted identical."""
    from repro.core.policy import LoopPredictionFrequencyTable, PredictionFrequencyTable

    rows = []
    G = 1024
    for name in ("ATAX", "Hotspot", "StreamTriad"):
        tr = _suite_trace(name, scale, cap)
        blocks = tr.block.astype(np.int64)
        batches = [blocks[i : i + G] for i in range(0, len(blocks), G)]

        def drive(make):
            t = make()
            t0 = time.time()
            for i, b in enumerate(batches):
                t.update(b)
                t.dense(tr.n_blocks)
                if i % 3 == 2:
                    t.on_intervals(3)  # exercise the flush path
            return time.time() - t0, t

        loop_s, t_loop = drive(LoopPredictionFrequencyTable)
        vec_s, t_vec = drive(PredictionFrequencyTable)
        assert np.array_equal(t_loop.tags, t_vec.tags) and np.array_equal(t_loop.counters, t_vec.counters), name
        n = len(blocks)
        rows.append({
            "benchmark": f"freq_table:{name}",
            "blocks": n,
            "loop_s": round(loop_s, 4),
            "vec_s": round(vec_s, 4),
            "speedup_x": round(loop_s / max(vec_s, 1e-9), 1),
            "loop_blocks_per_s": int(n / max(loop_s, 1e-9)),
            "vec_blocks_per_s": int(n / max(vec_s, 1e-9)),
        })
    agg = {
        "benchmark": "MANAGER_AGGREGATE",
        "blocks": sum(r["blocks"] for r in rows),
        "loop_s": round(sum(r["loop_s"] for r in rows), 4),
        "vec_s": round(sum(r["vec_s"] for r in rows), 4),
        "speedup_x": round(sum(r["loop_s"] for r in rows) / max(sum(r["vec_s"] for r in rows), 1e-9), 1),
        "loop_blocks_per_s": int(np.mean([r["loop_blocks_per_s"] for r in rows])),
        "vec_blocks_per_s": int(np.mean([r["vec_blocks_per_s"] for r in rows])),
    }
    return [agg] + rows


def bench_kernels(scale: float, cap: int, smoke: bool = False) -> list[dict]:
    """The `--kernels` section (ISSUE 10): the Pallas victim-selection and
    frequency-table kernels against the scan/numpy default paths.

    Per benchmark: a full EQUIV_CELLS `run_batch` sweep on the scan path vs
    REPRO_SIM_KERNELS' kernel path (counters asserted bit-identical — the
    hard gate), and the manager's freq-table stream through the host table
    vs `PallasPredictionFrequencyTable` (state asserted identical).  On CPU
    backends the kernels run in INTERPRET mode, so the wall-clock ratio is
    a regression bound, not a win; compiled-path numbers are recorded as
    pending a TPU/GPU run (`mode` says which this was).
    """
    from repro.core.policy import PallasPredictionFrequencyTable, PredictionFrequencyTable
    from repro.kernels.freq_table import ops as ft_ops

    mode = "interpret" if ft_ops.default_interpret() else "compiled"
    rows = []
    G = 1024
    for name in (("ATAX",) if smoke else ("ATAX", "Hotspot", "StreamTriad")):
        tr = _suite_trace(name, scale, cap)
        n = len(tr)

        def sweep(kernels):
            t0 = time.time()
            out = S.run_batch(tr, SWEEP_CELLS, kernels=kernels)
            return time.time() - t0, out

        sweep(False), sweep(True)  # warm both compile caches
        scan_s, scan_out = sweep(False)
        kern_s, kern_out = sweep(True)
        assert scan_out == kern_out, f"kernel path diverged from scan path on {name}"
        rows.append({
            "benchmark": f"evict_select:{name}",
            "mode": mode,
            "accesses": n,
            "scan_s": round(scan_s, 4),
            "kernel_s": round(kern_s, 4),
            "kernel_vs_scan_x": round(kern_s / max(scan_s, 1e-9), 2),
            "kernel_cell_acc_per_s": int(len(SWEEP_CELLS) * n / max(kern_s, 1e-9)),
        })

        blocks = tr.block.astype(np.int64)
        batches = [blocks[i : i + G] for i in range(0, len(blocks), G)]

        def drive(make):
            t = make()
            t0 = time.time()
            for i, b in enumerate(batches):
                t.update(b)
                t.lookup_many(b[: G // 4])
                if i % 3 == 2:
                    t.on_intervals(3)
            return time.time() - t0, t

        drive(PallasPredictionFrequencyTable)  # warm the kernel compile cache
        host_s, t_host = drive(PredictionFrequencyTable)
        pall_s, t_pall = drive(PallasPredictionFrequencyTable)
        assert np.array_equal(t_host.tags, t_pall.tags) and np.array_equal(
            t_host.counters, t_pall.counters), f"pallas freq table diverged on {name}"
        rows.append({
            "benchmark": f"freq_kernel:{name}",
            "mode": mode,
            "blocks": len(blocks),
            "host_s": round(host_s, 4),
            "kernel_s": round(pall_s, 4),
            "kernel_vs_host_x": round(pall_s / max(host_s, 1e-9), 2),
            "kernel_blocks_per_s": int(len(blocks) / max(pall_s, 1e-9)),
        })
    return rows


def bench_multi_tenant(scale: float, cap: int) -> dict:
    """The `--manager` section's multi-tenant row: one TenantMux (per-tenant
    pipelines, batched predictor dispatches) against one merged-stream
    manager on the SAME Section V-F concurrent trace — streaming protocol
    only (no simulator), SMOKE predictor, so the row isolates the mux's
    demux/dispatch overhead and records the top-1 win."""
    from repro.configs.predictor_paper import SMOKE
    from repro.core.incremental import TrainConfig
    from repro.uvm import runtime as R
    from repro.uvm.manager import FaultBatch, Outcomes

    parts = [_suite_trace(n, scale, cap) for n in ("StreamTriad", "Hotspot")]
    tr = T.concurrent(parts, seed=0, slice_len=512)
    tr = tr.slice(0, min(len(tr), 8000))  # bound the row's wall clock
    tcfg = TrainConfig(group_size=512, epochs=1, batch_size=128)

    def drive(multi_tenant: bool):
        mgr = (R.mux_for if multi_tenant else R.manager_for)(tr, SMOKE, tcfg)
        t0 = time.time()
        fc = 0
        for g0 in range(0, len(tr), tcfg.group_size):
            g1 = min(g0 + tcfg.group_size, len(tr))
            mgr.observe(FaultBatch(
                tr.page[g0:g1], tr.pc[g0:g1], tr.tb[g0:g1], tr.kernel[g0:g1],
                tenant=tr.tenant[g0:g1] if multi_tenant else None,
            ))
            fc += (g1 - g0) // 4  # a plausible far-fault rate for the clock
            mgr.feedback(Outcomes(fault_count=fc))
        return time.time() - t0, mgr.top1

    drive(False), drive(True)  # warm both paths' jit caches (fresh managers each drive)
    merged_s, merged_top1 = drive(False)
    mux_s, mux_top1 = drive(True)
    return {
        "benchmark": f"mux:{tr.name}",
        "accesses": len(tr),
        "merged_s": round(merged_s, 3),
        "mux_s": round(mux_s, 3),
        "overhead_x": round(mux_s / max(merged_s, 1e-9), 2),
        "merged_top1": round(merged_top1, 3),
        "mux_top1": round(mux_top1, 3),
        "mux_acc_per_s": int(len(tr) / max(mux_s, 1e-9)),
    }


def bench_qos(scale: float, cap: int) -> dict:
    """The `--manager` section's QoS row (PR 9): what budgeted capacity
    partitioning costs on the streaming path.  Drives the SAME concurrent
    trace through a plain TenantMux and a budgeted one (BudgetController:
    first-toucher block claims, per-round pressure scoring, elastic budget
    recompute, plus the per-round `evict_pref` sweep the runtime performs)
    — the controller is pure host-side numpy bookkeeping, so the warm
    overhead must stay well under 1.1x."""
    from repro.configs.predictor_paper import SMOKE
    from repro.core.incremental import TrainConfig
    from repro.uvm import runtime as R
    from repro.uvm.api import QosSpec, QosTierSpec
    from repro.uvm.manager import FaultBatch, Outcomes

    parts = [_suite_trace(n, scale, cap) for n in ("StreamTriad", "Hotspot")]
    tr = T.concurrent(parts, seed=0, slice_len=512)
    tr = tr.slice(0, min(len(tr), 8000))  # bound the row's wall clock
    tcfg = TrainConfig(group_size=512, epochs=1, batch_size=128)
    spec = QosSpec(tiers=(QosTierSpec("StreamTriad", floor=0.5, share=1.0),
                          QosTierSpec("Hotspot", floor=0.3, share=1.0)))

    def drive(budgeted: bool):
        mgr = R.mux_for(tr, SMOKE, tcfg, qos=spec if budgeted else None)
        # a plausible half-resident device, sized to the manager's padded
        # block bucket (what the runtime's simulator state hands evict_pref)
        resident = np.zeros(mgr.cfg.n_blocks, dtype=bool)
        resident[::2] = True
        t0 = time.time()
        fc = 0
        for g0 in range(0, len(tr), tcfg.group_size):
            g1 = min(g0 + tcfg.group_size, len(tr))
            mgr.observe(FaultBatch(
                tr.page[g0:g1], tr.pc[g0:g1], tr.tb[g0:g1], tr.kernel[g0:g1],
                tenant=tr.tenant[g0:g1],
            ))
            mgr.evict_pref(resident)  # the runtime calls this every group
            fc += (g1 - g0) // 4  # a plausible far-fault rate for the clock
            mgr.feedback(Outcomes(fault_count=fc))
        return time.time() - t0, mgr

    drive(False), drive(True)  # warm both paths' jit caches (fresh managers each drive)
    shared_s, _ = drive(False)
    qos_s, mgr = drive(True)
    assert mgr.qos is not None and mgr.qos.budgets, "budgeted drive produced no budgets"
    return {
        "benchmark": f"qos:{tr.name}",
        "accesses": len(tr),
        "shared_s": round(shared_s, 3),
        "qos_s": round(qos_s, 3),
        "overhead_x": round(qos_s / max(shared_s, 1e-9), 2),
        "budgets": {str(k): int(v) for k, v in mgr.qos.budgets.items()},
        "qos_acc_per_s": int(len(tr) / max(qos_s, 1e-9)),
    }


def bench_fault_tolerance(scale: float, cap: int) -> dict:
    """The `--manager` section's fault-tolerance row (PR 6): what resilience
    costs.  Times `state()` serialization, a SnapshotStore save/restore
    roundtrip (atomic publish + content-hash verify), and the degraded-mode
    observe path against the healthy learned path on the same stream — the
    degraded run wraps the trainer in a 100%-rate chaos fault so every
    round is served by the rule-based floor through the health machine."""
    import pickle
    import tempfile

    from repro.configs.predictor_paper import SMOKE
    from repro.core.incremental import TrainConfig
    from repro.uvm import runtime as R
    from repro.uvm.manager import (
        ChaosSchedule,
        FaultBatch,
        FaultInjector,
        HealthConfig,
        Outcomes,
        SnapshotStore,
    )

    tr = _suite_trace("ATAX", scale, cap)
    tr = tr.slice(0, min(len(tr), 8000))  # bound the row's wall clock
    tcfg = TrainConfig(group_size=512, epochs=1, batch_size=128)
    health = HealthConfig()

    def drive(chaos: bool):
        mgr = R.manager_for(tr, SMOKE, tcfg, health=health)
        if chaos:
            mgr.trainer = FaultInjector(
                ChaosSchedule(trainer_exc=1.0, seed=0)).wrap_trainer(mgr.trainer)
        t0 = time.time()
        fc = 0
        for g0 in range(0, len(tr), tcfg.group_size):
            g1 = min(g0 + tcfg.group_size, len(tr))
            mgr.observe(FaultBatch(tr.page[g0:g1], tr.pc[g0:g1], tr.tb[g0:g1], tr.kernel[g0:g1]))
            fc += (g1 - g0) // 4  # a plausible far-fault rate for the clock
            mgr.feedback(Outcomes(fault_count=fc))
        return time.time() - t0, mgr

    drive(False)  # warm the jit caches (fresh manager below)
    healthy_s, mgr = drive(False)
    degraded_s, chaos_mgr = drive(True)
    assert chaos_mgr.n_fallbacks > 0, "100%-rate trainer fault produced no fallback rounds"

    reps = 5
    t0 = time.time()
    for _ in range(reps):
        state = mgr.state()
    state_ms = (time.time() - t0) * 1000 / reps
    snapshot_bytes = len(pickle.dumps(state))

    with tempfile.TemporaryDirectory() as d:
        store = SnapshotStore(d)
        t0 = time.time()
        store.save(1, state)
        save_ms = (time.time() - t0) * 1000
        t0 = time.time()
        _, restored, _ = store.restore()
        restore_ms = (time.time() - t0) * 1000
    m2 = R.manager_for(tr, SMOKE, tcfg, health=health)
    m2.restore(restored)  # the roundtripped state must still load

    rounds = max(1, -(-len(tr) // tcfg.group_size))
    return {
        "benchmark": f"fault_tolerance:{tr.name}",
        "accesses": len(tr),
        "healthy_s": round(healthy_s, 3),
        "degraded_s": round(degraded_s, 3),
        "degraded_x": round(degraded_s / max(healthy_s, 1e-9), 2),
        "fallback_rounds": int(chaos_mgr.n_fallbacks),
        "rounds": rounds,
        "state_ms": round(state_ms, 2),
        "snapshot_bytes": snapshot_bytes,
        "save_ms": round(save_ms, 2),
        "restore_ms": round(restore_ms, 2),
    }


from repro.uvm.api.specs import SCALE_PRESETS, parse_scale  # noqa: E402


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true", help="3 benchmarks, sanity-gated (CI)")
    ap.add_argument("--scale", default="quick",
                    help="'quick' (0.4x, cap 6000), 'paper' (full generator sizes, cap 60000"
                         " — records wall clock into BENCH_sim.json), or a float")
    ap.add_argument("--cap", type=int, default=None, help="max trace length (overrides the scale preset)")
    ap.add_argument("--manager", action="store_true",
                    help="also run the manager section (vectorized vs loop frequency table);"
                         " with --update-baseline, record it into BENCH_sim.json")
    ap.add_argument("--kernels", action="store_true",
                    help="also run the kernels section (Pallas victim-select + freq-table"
                         " vs scan/host paths, bit-identity gated); with --update-baseline,"
                         " record it into BENCH_sim.json")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the committed BENCH_sim.json 'after' section")
    args = ap.parse_args(argv)
    args.scale, args.cap = parse_scale(args.scale, args.cap)
    paper_scale = (args.scale, args.cap) == SCALE_PRESETS["paper"]

    if args.manager:
        t0 = time.time()
        mrows = bench_manager(args.scale, args.cap)
        emit("sim_perf_manager", mrows, t0)
        t0 = time.time()
        mux_row = bench_multi_tenant(args.scale, args.cap)
        emit("sim_perf_manager_mux", [mux_row], t0)
        t0 = time.time()
        ft_row = bench_fault_tolerance(args.scale, args.cap)
        emit("sim_perf_manager_fault_tolerance", [ft_row], t0)
        t0 = time.time()
        qos_row = bench_qos(args.scale, args.cap)
        emit("sim_perf_manager_qos", [qos_row], t0)
        assert mrows[0]["speedup_x"] >= 2.0, mrows[0]  # vectorization must actually pay
        # the mux's demux + per-tenant dispatch overhead must stay modest
        # (it runs the SAME number of predictor samples, just partitioned)
        assert mux_row["overhead_x"] < 5.0, mux_row
        # the degraded floor skips the learned dispatch entirely, so an
        # all-faults run must not cost more than a small multiple of the
        # healthy run (recovery retries still dispatch-and-fail)
        assert ft_row["degraded_x"] < 5.0, ft_row
        # the budget controller is host-side numpy bookkeeping layered on
        # the same predictor dispatches — warm overhead must stay marginal
        assert qos_row["overhead_x"] < 1.1, qos_row
        # the committed record follows the file's convention: rewrite only
        # on an explicit --update-baseline, never from a routine/CI run
        if args.update_baseline and BASELINE_PATH.exists():
            base = json.loads(BASELINE_PATH.read_text())
            base["manager"] = {
                "freq_table_update": {
                    "before_loop": {k: mrows[0][k] for k in ("loop_s", "loop_blocks_per_s")},
                    "after_vectorized": {k: mrows[0][k] for k in ("vec_s", "vec_blocks_per_s", "speedup_x")},
                },
                "multi_tenant": mux_row,
                "fault_tolerance": ft_row,
                "qos": qos_row,
                "rows": mrows,
            }
            BASELINE_PATH.write_text(json.dumps(base, indent=2) + "\n")
            print(f"# recorded manager section into {BASELINE_PATH}")
        print("# manager section ok")
        # fall through: --manager ADDS the section to the requested run

    if args.kernels:
        t0 = time.time()
        krows = bench_kernels(args.scale, args.cap, smoke=args.smoke)
        evict_rows = [r for r in krows if r["benchmark"].startswith("evict_select:")]
        freq_rows = [r for r in krows if r["benchmark"].startswith("freq_kernel:")]
        emit("sim_perf_kernels_evict", evict_rows, t0)
        emit("sim_perf_kernels_freq", freq_rows, t0)
        # Bit-identity is asserted inside bench_kernels (the hard gate).
        # The wall-clock gates are regression bounds only.  evict_select
        # compares two jitted JAX paths, so its ratio is meaningful even
        # in interpret mode (~2x measured; bound 10x).  freq_kernel
        # compares against the pure-numpy host table, which interpret
        # mode cannot touch (per-block Python dispatch) — that ratio is
        # gated only on a compiled backend and recorded otherwise.
        for r in evict_rows:
            assert r["kernel_vs_scan_x"] < 10.0, r
        if krows[0]["mode"] == "compiled":
            for r in freq_rows:
                assert r["kernel_vs_host_x"] < 10.0, r
        if args.update_baseline and BASELINE_PATH.exists():
            base = json.loads(BASELINE_PATH.read_text())
            base["kernels"] = {
                "mode": krows[0]["mode"],
                "bit_identical_to_scan_path": True,
                "compiled_backend": ("recorded" if krows[0]["mode"] == "compiled"
                                     else "pending (CPU-only host: interpret mode)"),
                "rows": krows,
            }
            BASELINE_PATH.write_text(json.dumps(base, indent=2) + "\n")
            print(f"# recorded kernels section into {BASELINE_PATH}")
        print("# kernels section ok")
        # fall through: --kernels ADDS the section to the requested run

    names = ["ATAX", "Hotspot", "StreamTriad"] if args.smoke else list(T.BENCHMARKS)
    t0 = time.time()
    rows = [bench_one(_suite_trace(n, args.scale, args.cap)) for n in names]
    # Section V-F multi-tenant cell: two pattern classes interleaved at
    # scheduler-slice granularity in disjoint page ranges
    conc = T.concurrent(
        [_suite_trace("StreamTriad", args.scale, args.cap), _suite_trace("Hotspot", args.scale, args.cap)]
    )
    rows.append(bench_one(conc, name=f"concurrent:{conc.name}"))
    agg = {
        "benchmark": "AGGREGATE",
        "accesses": sum(r["accesses"] for r in rows),
        "events": sum(r["events"] for r in rows),
        "events_rle": sum(r["events_rle"] for r in rows),
        "compress_x": round(sum(r["accesses"] for r in rows) / max(sum(r["events"] for r in rows), 1), 2),
        "compress_rle_x": round(sum(r["accesses"] for r in rows) / max(sum(r["events_rle"] for r in rows), 1), 2),
        "cold_s": round(sum(r["cold_s"] for r in rows), 3),
        "warm_s": round(sum(r["warm_s"] for r in rows), 4),
        "warm_acc_per_s": int(np.mean([r["warm_acc_per_s"] for r in rows])),
        "sweep16_s": round(sum(r["sweep16_s"] for r in rows), 3),
        "sweep_cell_acc_per_s": int(np.mean([r["sweep_cell_acc_per_s"] for r in rows])),
    }
    rows.insert(0, {**agg, "derived": f"warm_{agg['warm_acc_per_s']}acc/s"})
    emit("sim_perf", rows, t0)

    if BASELINE_PATH.exists():
        base = json.loads(BASELINE_PATH.read_text())
        before = base.get("before", {}).get("table1_table6_quick_s")
        after = base.get("after", {}).get("table1_table6_quick_s")
        if before and after:
            print(f"# committed baseline: table1+table6 quick {before}s -> {after}s "
                  f"({before / after:.1f}x); this run's sweep throughput above")
        if paper_scale and not args.smoke:
            # the ROADMAP follow-up: paper-scale wall clock tracked alongside
            # the quick-suite trajectory (full generator sizes, cap 60000)
            base["paper_scale"] = {
                "suite_total_s": round(time.time() - t0, 1),
                "aggregate": agg,
                "rows": rows[1:],  # per-benchmark (AGGREGATE row is `aggregate`)
            }
            BASELINE_PATH.write_text(json.dumps(base, indent=2) + "\n")
            print(f"# recorded paper-scale wall clock into {BASELINE_PATH}")
        if args.update_baseline:
            base.setdefault("after", {})["sim_perf_rows"] = rows
            BASELINE_PATH.write_text(json.dumps(base, indent=2) + "\n")
            print(f"# updated {BASELINE_PATH}")

    if args.smoke:
        # CI sanity gates: event compression must actually engage on the
        # smoke set (compress_x == 1.0 would mean it is disabled), period-p
        # compression must beat plain RLE on the streaming benchmark
        # (StreamTriad: RLE 1.0x vs periodic >= 3x — the ISSUE 2 target),
        # and the warm path must beat one access per millisecond
        assert agg["compress_x"] >= 1.5, agg
        stream = next(r for r in rows if r["benchmark"] == "StreamTriad")
        assert stream["compress_x"] >= 3.0 > stream["compress_rle_x"], stream
        assert agg["warm_acc_per_s"] > 10_000, agg
        _sharded_lane_check(args.scale, args.cap)
        print("# smoke ok")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
