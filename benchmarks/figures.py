"""Paper figures: 3 (slowdown vs oversubscription), 4/11 (online vs offline vs
ours accuracy), 6 (single vs multi model), 10 (predictor architecture zoo),
12 (thrashing-term ablation), 13 (prediction-overhead sensitivity), 14
(normalized IPC vs UVMSmart)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import FEATURED, emit
from repro.uvm.api import Session


def fig3(ctx: Session):
    t0 = time.time()
    oversubs = (1.0, 1.1, 1.25, 1.5)
    rows = []
    for b in ctx.benches:
        # one vmapped scan sweeps the whole oversubscription axis
        stats = ctx.sims(b, [("lru", "tree", os_) for os_ in oversubs])
        r = {"benchmark": b}
        ref = None
        for os_, st in zip(oversubs, stats):
            ipc = ctx.ipc(b, st)
            ref = ipc if ref is None else ref
            r[f"slowdown_{os_}"] = round(1 - ipc / ref, 4)
        rows.append(r)
    emit("fig3_slowdown", rows, t0)
    return rows


def fig4(ctx: Session, benches=None):
    """Online vs offline top-1 accuracy (the online-training gap)."""
    t0 = time.time()
    rows = []
    for b in benches or FEATURED:
        on = ctx.protocol(b, "online_single")
        off = ctx.protocol(b, "offline")
        rows.append({
            "benchmark": b, "online_top1": round(on.top1, 3), "offline_top1": round(off.top1, 3),
            "gap": round(off.top1 - on.top1, 3), "n_classes": on.n_classes,
        })
    emit("fig4_online_offline", rows, t0)
    return rows


def fig6(ctx: Session):
    """Hotspot: offline vs online-multi-model vs online-single-model."""
    t0 = time.time()
    b = "Hotspot"
    rows = [{
        "benchmark": b,
        "offline": round(ctx.protocol(b, "offline").top1, 3),
        "online_multi": round(ctx.protocol(b, "online_multi").top1, 3),
        "online_single": round(ctx.protocol(b, "online_single").top1, 3),
    }]
    emit("fig6_multimodel", rows, t0)
    return rows


def fig10(ctx: Session, benches=None):
    """Predictor architecture zoo under online training."""
    t0 = time.time()
    rows = []
    for b in benches or ["Hotspot", "ATAX", "StreamTriad"]:
        r = {"benchmark": b}
        for kind in ("transformer", "lstm", "cnn", "mlp"):
            r[kind] = round(ctx.protocol(b, "online_single", kind=kind).top1, 3)
        r["derived"] = "transformer_best" if r["transformer"] >= max(r["lstm"], r["cnn"], r["mlp"]) - 0.02 else "see_row"
        rows.append(r)
    emit("fig10_model_zoo", rows, t0)
    return rows


def fig11(ctx: Session, benches=None):
    """Normalized top-1 (online & ours, relative to offline upper bound).
    Ours uses the paper's pretrain-then-finetune protocol (Section V-A);
    the pretrained table is shared and fine-tuned ACROSS the featured
    benchmarks in row order (a protocol chain — each link starts from the
    table the previous links left behind)."""
    import dataclasses

    t0 = time.time()
    benches = benches or FEATURED
    pretrain = dataclasses.replace(ctx.default_pretrain, seed0=123)
    ours_chain = ctx.protocol_chain(benches, "ours", pretrain=pretrain)
    rows = []
    for b, ours_res in zip(benches, ours_chain):
        off = ctx.protocol(b, "offline").top1
        on = ctx.protocol(b, "online_single").top1
        ours = ours_res.top1
        rows.append({
            "benchmark": b,
            "online_norm": round(on / max(off, 1e-9), 3),
            "ours_norm": round(ours / max(off, 1e-9), 3),
            "offline": round(off, 3),
            "derived": f"ours_gain={ours - on:+.3f}",
        })
    emit("fig11_normalized_acc", rows, t0)
    return rows


def fig12(ctx: Session):
    """Thrashing-term ablation on the 4 worst-thrashing benchmarks."""
    t0 = time.time()
    rows = []
    for b in ["ATAX", "BICG", "NW", "Srad-v2"]:
        w = ctx.ours(b, use_thrash_term=True)
        wo = ctx.ours(b, use_thrash_term=False)
        rows.append({
            "benchmark": b,
            "with_term_thrash": w.stats["pages_thrashed"],
            "without_term_thrash": wo.stats["pages_thrashed"],
            "with_term_top1": round(w.top1, 3),
            "without_term_top1": round(wo.top1, 3),
        })
    emit("fig12_thrash_term", rows, t0)
    return rows


def fig13(ctx: Session, benches=None):
    """Normalized IPC vs prediction overhead {1,10,20,50,100} us (vs UVMSmart)."""
    t0 = time.time()
    rows = []
    means = {}
    for b in benches or FEATURED:
        ours = ctx.ours(b)
        smart_ipc = ctx.ipc(b, ctx.uvmsmart(b))
        r = {"benchmark": b}
        for us in (1, 10, 20, 50, 100):
            # LearnedRunResult.ipc charges prediction overhead on the
            # fault-handling path (the predictor itself is asynchronous);
            # the result carries its own trace length
            ipc = ours.ipc(pred_overhead_us=us)
            r[f"norm_ipc_{us}us"] = round(ipc / smart_ipc, 3)
            means.setdefault(us, []).append(ipc / smart_ipc)
        rows.append(r)
    rows.insert(0, {"benchmark": "MEAN", **{f"norm_ipc_{u}us": round(float(np.mean(v)), 3) for u, v in means.items()}})
    emit("fig13_overhead", rows, t0)
    return rows


def fig14(ctx: Session, benches=None):
    """Normalized IPC (vs UVMSmart) at 125% and 150% oversubscription."""
    t0 = time.time()
    rows = []
    for b in benches or FEATURED:
        r = {"benchmark": b}
        for os_ in (1.25, 1.5):
            ours = ctx.ours(b, oversub=os_) if os_ != 1.25 else ctx.ours(b)
            smart_ipc = ctx.ipc(b, ctx.uvmsmart(b, os_))
            ipc = ours.ipc(pred_overhead_us=1.0)
            r[f"norm_ipc_{os_}"] = round(ipc / smart_ipc, 3)
        rows.append(r)
    emit("fig14_ipc", rows, t0)
    return rows
