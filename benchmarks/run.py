"""Benchmark harness: one entry per paper table/figure (+ the roofline
summary from the committed dry-run records).

    PYTHONPATH=src python -m benchmarks.run            # quick (reduced traces)
    PYTHONPATH=src python -m benchmarks.run --scale paper
    PYTHONPATH=src python -m benchmarks.run --only table6 fig14

Output: `name,us_per_call,derived` CSV lines + experiments/bench/<name>.csv.
"""
from __future__ import annotations

import argparse
import json
import time
from pathlib import Path

from benchmarks import figures, tables
from benchmarks.common import emit
from repro.uvm.api import Session


def roofline_summary(_ctx):
    """Summarise the committed multi-pod dry-run (EXPERIMENTS.md source)."""
    t0 = time.time()
    d = Path("experiments/dryrun")
    rows = []
    if d.exists():
        for f in sorted(d.glob("*__single.json")):
            r = json.loads(f.read_text())
            if r.get("status") != "ok":
                rows.append({"arch": r["arch"], "shape": r["shape"], "bottleneck": r.get("reason", r["status"])[:40], "compute_s": "", "memory_s": "", "collective_s": "", "useful": ""})
                continue
            rl = r["roofline"]
            rows.append({
                "arch": r["arch"], "shape": r["shape"], "bottleneck": rl["bottleneck"],
                "compute_s": f"{rl['compute_s']:.3e}", "memory_s": f"{rl['memory_s']:.3e}",
                "collective_s": f"{rl['collective_s']:.3e}", "useful": round(rl["useful_ratio"], 2),
            })
    emit("roofline_summary", rows, t0)
    return rows


SUITES = {
    "fig3": figures.fig3,
    "fig4": figures.fig4,
    "fig6": figures.fig6,
    "fig10": figures.fig10,
    "fig11": figures.fig11,
    "fig12": figures.fig12,
    "fig13": figures.fig13,
    "fig14": figures.fig14,
    "table1": tables.table1,
    "table2": tables.table2,
    "table3": tables.table3,
    "table4": tables.table4,
    "table6": tables.table6,
    "table7": tables.table7,
    "table8": tables.table8,
    "table9": tables.table9,
    "table10": tables.table10,
    "roofline": roofline_summary,
}

# cheap first, NN-heavy later (shared caches warm up in order)
ORDER = ["roofline", "table1", "table2", "table3", "table4", "fig3", "fig4", "fig6", "fig10", "fig11", "fig12", "table6", "fig13", "fig14", "table7", "table8", "table9", "table10"]


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--scale", choices=["quick", "paper"], default="quick")
    ap.add_argument("--only", nargs="*", default=None)
    args = ap.parse_args(argv)
    ctx = Session.paper() if args.scale == "paper" else Session()
    names = args.only or ORDER
    t0 = time.time()
    for name in names:
        SUITES[name](ctx)
    print(f"# total {time.time() - t0:.0f}s, results in experiments/bench/")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
