"""Fault-stream server throughput: microbatched vs serial dispatch.

Measures the :class:`repro.uvm.server.FaultStreamServer` serving N
concurrent loadgen clients that replay the SAME deterministic exported
fault log (identical lanes share one vmap shape bucket, the best case the
cross-connection :class:`~repro.uvm.server.core.MicrobatchDispatcher` is
built for).  Two timed passes over an in-process unix-socket server:

* ``serial``  — ``microbatch=False``: per-connection dispatch, every
  session-tick is its own executor task + event-loop round-trip
  (dispatch-equivalent to N independent ``cli serve`` processes sharing
  warm jits — ``ticks`` in the output counts those round-trips);
* ``batched`` — the default lockstep engine: staged halves from every
  connection gather into ONE worker hop per tick.  The tick executes
  per :func:`repro.uvm.server.core._resolve_engine`: ``vmap`` (one
  ``evaluate_many``/``train_group_many`` across lanes) on multi-device,
  ``fused`` (warm serial jits swept inside the single hop) on one
  device, where the repo's benched policy is that the vmapped path
  costs more than serial (see BENCH_sim.json notes).

Reported per mode: wall clock, sustained faults/sec, closed-loop action
latency p50/p99 (observe line sent -> action record received).  The runs
are content-deterministic, so the bench doubles as a scale-out
bit-identity gate: every client's action stream must be byte-identical
across the two modes (and across clients — same log, same seed).

Usage::

    PYTHONPATH=src python -m benchmarks.serve_perf --smoke      # CI gate
    PYTHONPATH=src python -m benchmarks.serve_perf              # full scale
    PYTHONPATH=src python -m benchmarks.serve_perf --aot        # + AOT section
    PYTHONPATH=src python -m benchmarks.serve_perf --update-baseline

``--smoke`` asserts the acceptance gates (>= 32 concurrent sessions, zero
errors, batched strictly beating serial); ``--update-baseline`` rewrites
the committed ``BENCH_serve.json``.  ``--aot`` adds the compile-once
section: three fresh subprocesses time a cold first round under plain jit,
under ``enable_aot`` against an empty cache (export cost), and against the
populated cache (reload skips tracing); the jit and reloaded action
records must match byte-for-byte.
"""
from __future__ import annotations

import argparse
import asyncio
import io
import json
import subprocess
import sys
import tempfile
import time
from pathlib import Path

from benchmarks.common import emit  # noqa: F401 — also enables the XLA compile cache
from repro.configs.predictor_paper import SMOKE
from repro.core.incremental import TrainConfig, Trainer
from repro.uvm import trace as T
from repro.uvm.manager import HealthConfig, ManagerConfig
from repro.uvm.server import FaultStreamServer, ServerConfig
from repro.uvm.server.loadgen import make_connector, run_loadgen

BASELINE_PATH = Path(__file__).resolve().parent.parent / "BENCH_serve.json"

#: (n_clients, n_batches, pages_per_batch, timed_repeats)
SCALES = {"smoke": (32, 6, 192, 2), "full": (64, 10, 256, 2)}


def make_workload(n_batches: int, batch: int):
    """One deterministic exported fault log + the manager config that
    serves it (SMOKE predictor: the bench times dispatch, not the model)."""
    tr = T.get_trace("StreamTriad", scale=1.0).slice(0, n_batches * batch)
    buf = io.StringIO()
    T.to_fault_log(tr, buf, batch=batch)
    lines = [ln for ln in buf.getvalue().splitlines() if ln and not ln.startswith("#")]
    assert len(lines) == n_batches, (len(lines), n_batches)
    mcfg = ManagerConfig(
        predictor=SMOKE,
        train=TrainConfig(group_size=batch, epochs=1, batch_size=64),
        n_pages=int(tr.n_pages), n_blocks=64, capacity=48,
        health=HealthConfig(),
    )
    return lines, mcfg


async def _serve_once(trainer, mcfg, lines, n_clients: int, *, microbatch: bool,
                      sock_dir: str, gather_spins: int = 2):
    cfg = ServerConfig(manager=mcfg, microbatch=microbatch, gather_spins=gather_spins,
                       exec_mode="auto")
    server = FaultStreamServer(cfg, trainer=trainer)
    path = str(Path(sock_dir) / f"serve-{'b' if microbatch else 's'}.sock")
    await server.start(path=path)
    try:
        stats = await run_loadgen(make_connector(f"unix:{path}"), lines, n_clients)
    finally:
        await server.shutdown()
    return stats, server


def run_mode(trainer, mcfg, lines, n_clients: int, *, microbatch: bool,
             sock_dir: str, repeats: int):
    """One untimed warmup pass (absorbs residual jit traces for this
    mode's dispatch shapes) then ``repeats`` timed passes; keep the best."""
    asyncio.run(_serve_once(trainer, mcfg, lines, n_clients,
                            microbatch=microbatch, sock_dir=sock_dir))
    best = None
    for _ in range(repeats):
        stats, server = asyncio.run(_serve_once(
            trainer, mcfg, lines, n_clients, microbatch=microbatch, sock_dir=sock_dir))
        if best is None or stats.wall_s < best[0].wall_s:
            best = (stats, server)
    return best


def prewarm_lanes(trainer, mcfg, lines, n_clients: int, sock_dir: str) -> None:
    """Compile every vmap lane-width bucket a timed batched run can hit
    (only relevant when the batched engine resolves to ``vmap``).

    Lane groups pad to the next power of two (>= ``MIN_VMAP_LANES``), so a
    tick that gathers 5..8 sessions hits the 8-wide executable and so on —
    run a short untimed pass at each power-of-two client count up to
    ``n_clients`` so no timed tick pays a fresh trace."""
    from repro.uvm.server.core import _resolve_engine

    if _resolve_engine("auto") != "vmap":
        return
    warm_lines = lines[: min(3, len(lines))]
    width = Trainer.MIN_VMAP_LANES
    while width <= n_clients:
        asyncio.run(_serve_once(trainer, mcfg, warm_lines, width,
                                microbatch=True, sock_dir=sock_dir))
        width *= 2


def bench_serve(scale: str):
    n_clients, n_batches, batch, repeats = SCALES[scale]
    lines, mcfg = make_workload(n_batches, batch)
    trainer = Trainer(mcfg.predictor, mcfg.train, mcfg.kind)
    rows, streams = [], {}
    with tempfile.TemporaryDirectory() as sock_dir:
        prewarm_lanes(trainer, mcfg, lines, n_clients, sock_dir)
        for mode, microbatch in (("serial", False), ("batched", True)):
            stats, server = run_mode(trainer, mcfg, lines, n_clients,
                                     microbatch=microbatch, sock_dir=sock_dir,
                                     repeats=repeats)
            streams[mode] = [r.actions for r in stats.per_client]
            rows.append({
                "mode": mode,
                "engine": server.dispatcher.engine if microbatch else "per-conn",
                "clients": stats.clients,
                "actions": stats.actions,
                "errors": stats.errors,
                "wall_s": round(stats.wall_s, 4),
                "faults_per_s": round(stats.faults_per_s, 1),
                "p50_ms": round(stats.p50_ms, 3),
                "p99_ms": round(stats.p99_ms, 3),
                "ticks": server.dispatcher.n_ticks,
                "max_eval_lanes": server.dispatcher.max_eval_lanes,
            })
    serial, batched = rows[0], rows[1]
    speedup = serial["wall_s"] / batched["wall_s"] if batched["wall_s"] > 0 else 0.0
    for r in rows:
        r["speedup_x"] = round(speedup, 3)
        r["derived"] = f"batched/serial speedup {speedup:.2f}x"
    # scale-out bit-identity: same log + same seeds => every client's
    # action stream is byte-identical across modes (and across clients)
    assert streams["serial"] == streams["batched"], "mode streams diverged"
    flat = [s for per_mode in streams.values() for s in per_mode]
    assert all(s == flat[0] for s in flat), "client streams diverged"
    return rows


# --- AOT section: compile-once export vs per-process jit tracing ------------

def _aot_child(mode: str, cache: str) -> int:
    """Fresh-process probe (``--aot-child``): time the first serve round."""
    from repro.uvm.manager import TenantMux
    from repro.uvm.server import SyncDispatch, StreamSession, drive
    from repro.uvm.server.aot import enable_aot

    lines, mcfg = make_workload(3, 192)
    trainer = Trainer(mcfg.predictor, mcfg.train, mcfg.kind)
    if mode != "jit":
        enable_aot(trainer, cache)
    session = StreamSession(TenantMux(mcfg, trainer=trainer), default_tenant="default")
    dispatch = SyncDispatch(trainer, mcfg.use_lucir)
    t0 = time.time()
    records = [r for ln in lines for r in drive(session.step(ln), dispatch)]
    records += drive(session.drain(), dispatch)
    out = {"mode": mode, "first_rounds_s": round(time.time() - t0, 3),
           "records": records}
    if mode != "jit":
        out["cache"] = trainer.aot_cache.stats()
    print(json.dumps(out))
    return 0


def bench_aot() -> dict:
    """Three fresh subprocesses: jit-cold, AOT export (populates the
    cache), AOT reload (trace+lower skipped).  Equality is part of the
    contract: the reloaded executables must reproduce the jit records."""
    out = {}
    with tempfile.TemporaryDirectory() as cache:
        for mode in ("jit", "export", "reload"):
            proc = subprocess.run(
                [sys.executable, "-m", "benchmarks.serve_perf",
                 "--aot-child", "export" if mode == "export" else
                 ("reload" if mode == "reload" else "jit"),
                 "--aot-cache", cache],
                capture_output=True, text=True, check=True)
            out[mode] = json.loads(proc.stdout.strip().splitlines()[-1])
    assert out["reload"]["cache"]["hits"] > 0, out["reload"]["cache"]
    assert out["reload"]["records"] == out["jit"]["records"], "AOT records != jit records"
    row = {
        "jit_cold_s": out["jit"]["first_rounds_s"],
        "aot_export_cold_s": out["export"]["first_rounds_s"],
        "aot_reload_cold_s": out["reload"]["first_rounds_s"],
        "reload_cache": out["reload"]["cache"],
        "records_equal": True,
        "derived": (f"reload {out['reload']['first_rounds_s']:.1f}s vs "
                    f"jit {out['jit']['first_rounds_s']:.1f}s cold"),
    }
    for m in out.values():
        m.pop("records", None)
    return row


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--smoke", action="store_true",
                    help="CI scale (32 clients), assert the acceptance gates")
    ap.add_argument("--aot", action="store_true",
                    help="also run the AOT compile-once section (3 subprocesses)")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite the committed BENCH_serve.json")
    ap.add_argument("--aot-child", help=argparse.SUPPRESS)
    ap.add_argument("--aot-cache", help=argparse.SUPPRESS)
    args = ap.parse_args(argv)
    if args.aot_child:
        return _aot_child(args.aot_child, args.aot_cache)

    scale = "smoke" if args.smoke else "full"
    n_clients, n_batches, _, _ = SCALES[scale]
    t0 = time.time()
    rows = bench_serve(scale)
    serial, batched = rows[0], rows[1]
    if args.smoke:
        # acceptance gates: N concurrent sessions served cleanly, the
        # dispatcher actually batched across connections, and the
        # microbatched mode measurably beats per-connection serial
        for r in rows:
            assert r["errors"] == 0, r
            assert r["actions"] == n_clients * n_batches, r
        assert batched["max_eval_lanes"] >= Trainer.MIN_VMAP_LANES, batched
        assert batched["speedup_x"] > 1.0, (serial, batched)
    aot_row = None
    if args.aot:
        aot_row = bench_aot()
    # the AOT row has its own schema; pad to the key union for one CSV
    all_rows = rows + ([aot_row] if aot_row else [])
    keys = list(dict.fromkeys(k for r in all_rows for k in r))
    emit("serve_perf", [{k: r.get(k, "") for k in keys} for r in all_rows], t0)

    if BASELINE_PATH.exists():
        base = json.loads(BASELINE_PATH.read_text())
        prev = base.get(scale, {}).get("speedup_x")
        if prev:
            print(f"# committed {scale} speedup {prev}x; this run {batched['speedup_x']}x")
    else:
        base = {}
    if args.update_baseline:
        base[scale] = {
            "clients": n_clients,
            "engine": batched["engine"],
            "speedup_x": batched["speedup_x"],
            "serial": {k: serial[k] for k in ("wall_s", "faults_per_s", "p50_ms", "p99_ms")},
            "batched": {k: batched[k] for k in
                        ("wall_s", "faults_per_s", "p50_ms", "p99_ms", "ticks", "max_eval_lanes")},
        }
        if aot_row is not None:
            base["aot"] = {k: v for k, v in aot_row.items() if k != "derived"}
        BASELINE_PATH.write_text(json.dumps(base, indent=2) + "\n")
        print(f"# recorded {scale} section into {BASELINE_PATH}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
