"""Paper tables: I (strategies w/o prefetch vs upper bound), II (HPE x
prefetcher interplay), IV (predictor footprint), VI (full strategy matrix),
VII (concurrent multi-workload accuracy), VIII (Section V-F concurrent
top-1 through the full runtime: TenantMux vs merged-single-manager)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import ALL_BENCH, emit
from repro.uvm.api import Session


def table1(ctx: Session):
    """Baseline / D.+HPE / UVMSmart / D.+Belady pages thrashed @125%."""
    t0 = time.time()
    ctx.uvmsmart_many(ctx.benches)  # independent runs overlap on the host
    rows = []
    for b in ctx.benches:
        rows.append({
            "benchmark": b,
            "baseline": ctx.sim(b, "lru", "tree")["pages_thrashed"],
            "d_hpe": ctx.sim(b, "hpe", "demand")["pages_thrashed"],
            "uvmsmart": ctx.uvmsmart(b)["pages_thrashed"],
            "d_belady": ctx.sim(b, "belady", "demand")["pages_thrashed"],
        })
    emit("table1_thrashing", rows, t0)
    # the paper's structural claims
    for r in rows:
        assert r["d_belady"] <= r["d_hpe"] + 1e-9, r
    return rows


def table2(ctx: Session):
    """Demand.+HPE vs Tree.+HPE (the interplay collapse)."""
    t0 = time.time()
    rows = []
    for b in ctx.benches:
        d = ctx.sim(b, "hpe", "demand")["pages_thrashed"]
        t = ctx.sim(b, "hpe", "tree")["pages_thrashed"]
        rows.append({"benchmark": b, "demand_hpe": d, "tree_hpe": t, "derived": f"collapse_x{t / max(d, 1):.0f}"})
    emit("table2_hpe_prefetch", rows, t0)
    return rows


def table3(ctx: Session):
    """Unique page deltas per program phase (the growing-class problem that
    motivates incremental learning; paper Table III)."""
    from repro.core.features import unique_deltas_per_phase

    t0 = time.time()
    rows = []
    for b in ctx.benches:
        p = unique_deltas_per_phase(ctx.trace(b), 3)
        rows.append({
            "benchmark": b, "phase0": p[0], "phase1": p[1], "phase2": p[2],
            "derived": f"growth_x{p[2] / max(p[0], 1):.1f}",
        })
    emit("table3_delta_growth", rows, t0)
    # NW / Srad must grow; streaming must stay flat (paper's central premise)
    by = {r["benchmark"]: r for r in rows}
    assert by["NW"]["phase2"] > by["NW"]["phase0"]
    assert by["StreamTriad"]["phase2"] <= by["StreamTriad"]["phase0"] + 2
    return rows


def table4(ctx: Session):
    """Predictor memory footprint with the paper's accounting (Eq. 4):
    Total = (Params*2 + Activations) * Patterns, 4-bit-ish quantised."""
    t0 = time.time()
    from repro.core.predictor import param_count

    rows = []
    n_params = param_count(ctx.pcfg)
    params_mb = n_params * 4 / 2**20  # fp32
    acti_mb = 1.46  # measured activation budget from the paper's Table IV
    for b in ctx.benches:
        from repro.core.pattern import PatternClassifier

        tr = ctx.trace(b)
        c = PatternClassifier()
        pats = set()
        G = ctx.tcfg.group_size
        for lo in range(0, len(tr), G):
            pats.add(c.classify(tr.block[lo : lo + G], tr.kernel[lo : lo + G]))
        total = (params_mb * 2 + acti_mb) * len(pats)
        rows.append({
            "benchmark": b, "params_mb": round(params_mb, 2), "acti_mb": acti_mb,
            "patterns": len(pats), "total_mb": round(total, 2),
        })
    emit("table4_footprint", rows, t0)
    return rows


def table6(ctx: Session):
    """Full strategy matrix incl. our solution (the headline table)."""
    t0 = time.time()
    ctx.ours_many(ctx.benches)  # independent learned runs overlap on the host
    rows = []
    reductions = []
    for b in ctx.benches:
        base = ctx.sim(b, "lru", "tree")["pages_thrashed"]
        ours = ctx.ours(b).stats["pages_thrashed"]
        smart = ctx.uvmsmart(b)["pages_thrashed"]
        rows.append({
            "benchmark": b,
            "baseline": base,
            "tree_hpe": ctx.sim(b, "hpe", "tree")["pages_thrashed"],
            "uvmsmart": smart,
            "ours": ours,
            "demand_hpe": ctx.sim(b, "hpe", "demand")["pages_thrashed"],
            "demand_belady": ctx.sim(b, "belady", "demand")["pages_thrashed"],
        })
        if base > 0:
            reductions.append(1 - ours / base)
    avg_red = float(np.mean(reductions)) if reductions else 0.0
    rows.insert(0, {"benchmark": "AVG_REDUCTION_VS_BASELINE", "baseline": "", "tree_hpe": "",
                    "uvmsmart": "", "ours": round(avg_red, 3), "demand_hpe": "", "demand_belady": "",
                    })
    emit("table6_thrashing_full", rows, t0)
    return rows


def table7(ctx: Session):
    """Concurrent multi-workload page-delta prediction (scalability).
    'Ours' follows the paper's Section V-A protocol: per-pattern models
    pretrained on a (different-input) corpus, then fine-tuned online."""
    import dataclasses

    t0 = time.time()
    pretrain = dataclasses.replace(ctx.default_pretrain, seed0=321)
    pairs = [("StreamTriad", "2DCONV"), ("Hotspot", "Srad-v2"), ("NW", "2DCONV"), ("ATAX", "Srad-v2")]
    rows = []
    for a, b in pairs:
        # slices aligned with the training group size: each group sees ONE
        # tenant's coherent stream, which is what the DFA classifies (per-access
        # mixing would blend pattern classes inside every group)
        w = ctx.concurrent((a, b), slice_len=ctx.tcfg.group_size)
        online = ctx.protocol(w, "online_single")
        ours = ctx.protocol(w, "ours", pretrain=pretrain)
        rows.append({
            "workloads": f"{a}+{b}", "online_top1": round(online.top1, 3),
            "ours_top1": round(ours.top1, 3), "derived": f"delta={ours.top1 - online.top1:+.3f}",
        })
    emit("table7_multiworkload", rows, t0)
    return rows


def table8(ctx: Session):
    """Section V-F concurrent top-1 through the FULL runtime (simulator in
    the loop): the multi-tenant `TenantMux` (one classifier->predictor
    pipeline per tenant, isolated frequency tables) against the
    merged-single-manager baseline that treats the interleaved stream as
    one workload.  The paper reports per-workload specialization is worth
    +10.2% top-1 on average (up to +30.2%); both columns run the Section
    V-A pretrain-then-finetune protocol over the same tenant-tagged
    merge."""
    t0 = time.time()
    pairs = [("StreamTriad", "2DCONV"), ("Hotspot", "Srad-v2"), ("NW", "2DCONV"), ("ATAX", "Srad-v2")]
    rows, deltas = [], []
    for a, b in pairs:
        # group-aligned scheduler slices, like table7: each observed batch
        # is ONE tenant's coherent stream, which is what the DFA classifies
        w = ctx.concurrent((a, b), slice_len=ctx.tcfg.group_size)
        mux = ctx.ours(w)  # ModelSpec.tenancy defaults to 'mux'
        merged = ctx.ours(w, tenancy="merged")
        per = {k: round(v, 3) for k, v in sorted((mux.per_tenant_top1 or {}).items())}
        rows.append({
            "workloads": f"{a}+{b}",
            "merged_top1": round(merged.top1, 3),
            "mux_top1": round(mux.top1, 3),
            "tenant0_top1": per.get("0", ""),
            "tenant1_top1": per.get("1", ""),
            "derived": f"delta={mux.top1 - merged.top1:+.3f}",
        })
        deltas.append(mux.top1 - merged.top1)
    avg = float(np.mean(deltas)) if deltas else 0.0
    rows.insert(0, {
        "workloads": "AVG_MUX_GAIN", "merged_top1": "", "mux_top1": "",
        "tenant0_top1": "", "tenant1_top1": "", "derived": f"delta={avg:+.3f}",
    })
    emit("table8_concurrent_mux", rows, t0)
    # the acceptance pin: per-tenant specialization must not lose to the
    # merged baseline on the Section V-F suite
    assert avg >= 0, rows
    return rows
