"""Paper tables: I (strategies w/o prefetch vs upper bound), II (HPE x
prefetcher interplay), IV (predictor footprint), VI (full strategy matrix),
VII (concurrent multi-workload accuracy), VIII (Section V-F concurrent
top-1 through the full runtime: TenantMux vs merged-single-manager),
IX (drift: re-classifying vs frozen-pattern managers on phase-changing zoo
traces — a subsystem result beyond the paper's tables), X (QoS fairness:
per-tenant thrash/IPC under an adversarial co-tenant, budgeted mux vs
shared pool — the PR 9 capacity-partitioning subsystem result)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import ALL_BENCH, emit
from repro.uvm.api import Session


def table1(ctx: Session):
    """Baseline / D.+HPE / UVMSmart / D.+Belady pages thrashed @125%."""
    t0 = time.time()
    ctx.uvmsmart_many(ctx.benches)  # independent runs overlap on the host
    rows = []
    for b in ctx.benches:
        rows.append({
            "benchmark": b,
            "baseline": ctx.sim(b, "lru", "tree")["pages_thrashed"],
            "d_hpe": ctx.sim(b, "hpe", "demand")["pages_thrashed"],
            "uvmsmart": ctx.uvmsmart(b)["pages_thrashed"],
            "d_belady": ctx.sim(b, "belady", "demand")["pages_thrashed"],
        })
    emit("table1_thrashing", rows, t0)
    # the paper's structural claims
    for r in rows:
        assert r["d_belady"] <= r["d_hpe"] + 1e-9, r
    return rows


def table2(ctx: Session):
    """Demand.+HPE vs Tree.+HPE (the interplay collapse)."""
    t0 = time.time()
    rows = []
    for b in ctx.benches:
        d = ctx.sim(b, "hpe", "demand")["pages_thrashed"]
        t = ctx.sim(b, "hpe", "tree")["pages_thrashed"]
        rows.append({"benchmark": b, "demand_hpe": d, "tree_hpe": t, "derived": f"collapse_x{t / max(d, 1):.0f}"})
    emit("table2_hpe_prefetch", rows, t0)
    return rows


def table3(ctx: Session):
    """Unique page deltas per program phase (the growing-class problem that
    motivates incremental learning; paper Table III)."""
    from repro.core.features import unique_deltas_per_phase

    t0 = time.time()
    rows = []
    for b in ctx.benches:
        p = unique_deltas_per_phase(ctx.trace(b), 3)
        rows.append({
            "benchmark": b, "phase0": p[0], "phase1": p[1], "phase2": p[2],
            "derived": f"growth_x{p[2] / max(p[0], 1):.1f}",
        })
    emit("table3_delta_growth", rows, t0)
    # NW / Srad must grow; streaming must stay flat (paper's central premise)
    by = {r["benchmark"]: r for r in rows}
    assert by["NW"]["phase2"] > by["NW"]["phase0"]
    assert by["StreamTriad"]["phase2"] <= by["StreamTriad"]["phase0"] + 2
    return rows


def table4(ctx: Session):
    """Predictor memory footprint with the paper's accounting (Eq. 4):
    Total = (Params*2 + Activations) * Patterns, 4-bit-ish quantised."""
    t0 = time.time()
    from repro.core.predictor import param_count

    rows = []
    n_params = param_count(ctx.pcfg)
    params_mb = n_params * 4 / 2**20  # fp32
    acti_mb = 1.46  # measured activation budget from the paper's Table IV
    for b in ctx.benches:
        from repro.core.pattern import PatternClassifier

        tr = ctx.trace(b)
        c = PatternClassifier()
        pats = set()
        G = ctx.tcfg.group_size
        for lo in range(0, len(tr), G):
            pats.add(c.classify(tr.block[lo : lo + G], tr.kernel[lo : lo + G]))
        total = (params_mb * 2 + acti_mb) * len(pats)
        rows.append({
            "benchmark": b, "params_mb": round(params_mb, 2), "acti_mb": acti_mb,
            "patterns": len(pats), "total_mb": round(total, 2),
        })
    emit("table4_footprint", rows, t0)
    return rows


def table6(ctx: Session):
    """Full strategy matrix incl. our solution (the headline table)."""
    t0 = time.time()
    ctx.ours_many(ctx.benches)  # independent learned runs overlap on the host
    rows = []
    reductions = []
    for b in ctx.benches:
        base = ctx.sim(b, "lru", "tree")["pages_thrashed"]
        ours = ctx.ours(b).stats["pages_thrashed"]
        smart = ctx.uvmsmart(b)["pages_thrashed"]
        rows.append({
            "benchmark": b,
            "baseline": base,
            "tree_hpe": ctx.sim(b, "hpe", "tree")["pages_thrashed"],
            "uvmsmart": smart,
            "ours": ours,
            "demand_hpe": ctx.sim(b, "hpe", "demand")["pages_thrashed"],
            "demand_belady": ctx.sim(b, "belady", "demand")["pages_thrashed"],
        })
        if base > 0:
            reductions.append(1 - ours / base)
    avg_red = float(np.mean(reductions)) if reductions else 0.0
    rows.insert(0, {"benchmark": "AVG_REDUCTION_VS_BASELINE", "baseline": "", "tree_hpe": "",
                    "uvmsmart": "", "ours": round(avg_red, 3), "demand_hpe": "", "demand_belady": "",
                    })
    emit("table6_thrashing_full", rows, t0)
    return rows


def table7(ctx: Session):
    """Concurrent multi-workload page-delta prediction (scalability).
    'Ours' follows the paper's Section V-A protocol: per-pattern models
    pretrained on a (different-input) corpus, then fine-tuned online."""
    import dataclasses

    t0 = time.time()
    pretrain = dataclasses.replace(ctx.default_pretrain, seed0=321)
    pairs = [("StreamTriad", "2DCONV"), ("Hotspot", "Srad-v2"), ("NW", "2DCONV"), ("ATAX", "Srad-v2")]
    rows = []
    for a, b in pairs:
        # slices aligned with the training group size: each group sees ONE
        # tenant's coherent stream, which is what the DFA classifies (per-access
        # mixing would blend pattern classes inside every group)
        w = ctx.concurrent((a, b), slice_len=ctx.tcfg.group_size)
        online = ctx.protocol(w, "online_single")
        ours = ctx.protocol(w, "ours", pretrain=pretrain)
        rows.append({
            "workloads": f"{a}+{b}", "online_top1": round(online.top1, 3),
            "ours_top1": round(ours.top1, 3), "derived": f"delta={ours.top1 - online.top1:+.3f}",
        })
    emit("table7_multiworkload", rows, t0)
    return rows


def table8(ctx: Session):
    """Section V-F concurrent top-1 through the FULL runtime (simulator in
    the loop): the multi-tenant `TenantMux` (one classifier->predictor
    pipeline per tenant, isolated frequency tables) against the
    merged-single-manager baseline that treats the interleaved stream as
    one workload.  The paper reports per-workload specialization is worth
    +10.2% top-1 on average (up to +30.2%); both columns run the Section
    V-A pretrain-then-finetune protocol over the same tenant-tagged
    merge."""
    t0 = time.time()
    pairs = [("StreamTriad", "2DCONV"), ("Hotspot", "Srad-v2"), ("NW", "2DCONV"), ("ATAX", "Srad-v2")]
    rows, deltas = [], []
    for a, b in pairs:
        # group-aligned scheduler slices, like table7: each observed batch
        # is ONE tenant's coherent stream, which is what the DFA classifies
        w = ctx.concurrent((a, b), slice_len=ctx.tcfg.group_size)
        mux = ctx.ours(w)  # ModelSpec.tenancy defaults to 'mux'
        merged = ctx.ours(w, tenancy="merged")
        per = {k: round(v, 3) for k, v in sorted((mux.per_tenant_top1 or {}).items())}
        rows.append({
            "workloads": f"{a}+{b}",
            "merged_top1": round(merged.top1, 3),
            "mux_top1": round(mux.top1, 3),
            "tenant0_top1": per.get("0", ""),
            "tenant1_top1": per.get("1", ""),
            "derived": f"delta={mux.top1 - merged.top1:+.3f}",
        })
        deltas.append(mux.top1 - merged.top1)
    avg = float(np.mean(deltas)) if deltas else 0.0
    rows.insert(0, {
        "workloads": "AVG_MUX_GAIN", "merged_top1": "", "mux_top1": "",
        "tenant0_top1": "", "tenant1_top1": "", "derived": f"delta={avg:+.3f}",
    })
    emit("table8_concurrent_mux", rows, t0)
    # the acceptance pin: per-tenant specialization must not lose to the
    # merged baseline on the Section V-F suite.  On failure, print the
    # per-pair breakdown so the CI log says WHICH pair regressed and by
    # how much, not just that the average went negative.
    if avg < 0:
        print(f"table8: AVG_MUX_GAIN {avg:+.3f} < 0 — per-pair breakdown:")
        for r in rows[1:]:
            print(f"  {r['workloads']:<24} merged={r['merged_top1']} "
                  f"mux={r['mux_top1']} {r['derived']}")
        raise AssertionError(f"avg mux gain {avg:+.3f} < 0 (see breakdown above)")
    return rows


def table9(ctx: Session):
    """Drift benchmark: streaming re-classification measured as a subsystem
    result on the zoo's phase-changing traces (benchmarks beyond the paper's
    tables; see docs/REPRODUCING.md).

    Each trace alternates a learnable streaming phase (StreamTriad) with the
    zoo's RandomScan noise phase (fresh uniform draws — unmemorizable).  A
    FROZEN-pattern manager (``reclass_interval`` so large the seed window
    never expires) funnels every phase into the pattern classified first, so
    the noise phases train straight into the streaming model; re-classifying
    managers (``reclass_interval=256/512``, hysteresis 2) quarantine the
    noise in the RANDOM entry and return to a warm, unpolluted model at each
    switch-back.  The rule-based ``hpe+tree`` column is the no-learning
    floor.  The headline assertion: the 256-fault re-classifier beats frozen
    on top-1 AND pages-thrashed on every row (strictly on average).

    The geometry is PINNED to quick scale (trace scale 0.4, the quick
    predictor, group 256) regardless of ``--scale`` — this is a subsystem
    pin like the golden suite, not a paper-scale table, and pinning keeps
    the committed BENCH_sim.json ``drift`` section byte-stable.  Rows are
    recorded into BENCH_sim.json (deterministic content only)."""
    import json
    from pathlib import Path

    from repro.configs.predictor_paper import CONFIG_QUICK
    from repro.uvm import runtime as R
    from repro.uvm.api.specs import PretrainSpec, TrainSpec

    t0 = time.time()
    FROZEN = 1 << 30  # seed window never expires: the frozen-pattern manager
    train = TrainSpec(group_size=256, epochs=2, batch_size=128)
    tcfg = train.to_train_config()
    pretrain = PretrainSpec(scale=0.24)  # quick Session.default_pretrain
    table = lambda: ctx.pretrained(pretrain, pcfg=CONFIG_QUICK, train=train)

    def learned(tr, oversub, **kw):
        mgr = R.manager_for(tr, CONFIG_QUICK, tcfg, oversubscription=oversub,
                            table=table(), **kw)
        res = R.run_ours(tr, CONFIG_QUICK, tcfg, oversubscription=oversub, manager=mgr)
        return res, mgr.n_pattern_switches

    cycle = ("StreamTriad", "RandomScan")
    suite = [  # (drifting workload, oversubscription)
        (ctx.drifting(cycle + ("StreamTriad",), scale=0.4, cap=6000, segment=1024), 1.25),
        (ctx.drifting(cycle * 2 + ("StreamTriad",), scale=0.4, cap=6400, segment=1280), 1.2),
        (ctx.drifting(cycle * 2 + ("StreamTriad",), scale=0.4, cap=6000, segment=1024), 1.3),
    ]
    rows, d_top1, d_thrash = [], [], []
    for w, oversub in suite:
        tr = ctx.trace(w)
        froz, _ = learned(tr, oversub, reclass_interval=FROZEN)
        r256, switches = learned(tr, oversub, reclass_interval=256, reclass_hysteresis=2)
        r512, _ = learned(tr, oversub, reclass_interval=512, reclass_hysteresis=2)
        rule = ctx.sim(w, "hpe", "tree", oversub)
        rows.append({
            "trace": tr.name.replace("drift:", ""),
            "oversub": oversub,
            "frozen_top1": round(froz.top1, 3),
            "frozen_thrash": froz.stats["pages_thrashed"],
            "reclass256_top1": round(r256.top1, 3),
            "reclass256_thrash": r256.stats["pages_thrashed"],
            "switches": switches,
            "reclass512_top1": round(r512.top1, 3),
            "reclass512_thrash": r512.stats["pages_thrashed"],
            "rule_thrash": rule["pages_thrashed"],
            "derived": f"dtop1={r256.top1 - froz.top1:+.3f}",
        })
        d_top1.append(r256.top1 - froz.top1)
        d_thrash.append(r256.stats["pages_thrashed"] - froz.stats["pages_thrashed"])
        # re-classification must actually fire (noise in, noise out, back):
        # >= 2 switches per trace, and the learned manager must stay far
        # below the no-learning floor on thrashing
        assert switches >= 2, rows
        assert r256.stats["pages_thrashed"] < rule["pages_thrashed"], rows
    avg_t1, avg_thr = float(np.mean(d_top1)), float(np.mean(d_thrash))
    rows.insert(0, {
        "trace": "AVG_RECLASS_VS_FROZEN", "oversub": "", "frozen_top1": "",
        "frozen_thrash": "", "reclass256_top1": "", "reclass256_thrash": "",
        "switches": "", "reclass512_top1": "", "reclass512_thrash": "",
        "rule_thrash": "", "derived": f"dtop1={avg_t1:+.3f} dthrash={avg_thr:+.0f}",
    })
    emit("table9_drift_reclass", rows, t0)
    # THE drift claim: periodic re-classification beats the frozen-pattern
    # manager on BOTH metrics — never worse on any phase-changing trace,
    # strictly better on average
    assert all(d >= 0 for d in d_top1) and avg_t1 > 0, rows
    assert all(d <= 0 for d in d_thrash) and avg_thr < 0, rows
    # record the subsystem result (deterministic content only) into the
    # committed benchmark ledger
    bench = _bench_ledger()
    data = json.loads(bench.read_text())
    data["drift"] = {
        "benchmark": "PYTHONPATH=src python -m benchmarks.run --only table9",
        "headline": {
            "avg_top1_delta_reclass256_vs_frozen": round(avg_t1, 4),
            "avg_pages_thrashed_delta": round(avg_thr, 1),
            "notes": "re-classifying manager (interval 256, hysteresis 2) vs "
                     "frozen-pattern manager on phase-changing zoo traces "
                     "(StreamTriad x RandomScan cycles), quick-pinned geometry; "
                     "interval 512 is too coarse to switch on the 1024-access "
                     "phases and collapses onto the frozen manager",
        },
        "rows": rows,
    }
    bench.write_text(json.dumps(data, indent=2) + "\n")
    return rows


def _bench_ledger():
    from pathlib import Path

    return Path(__file__).resolve().parent.parent / "BENCH_sim.json"


def table10(ctx: Session):
    """QoS fairness: per-tenant pages-thrashed and IPC under an adversarial
    co-tenant, budgeted mux vs today's shared pool (PR 9 subsystem result
    beyond the paper's tables).

    Each scenario merges one well-behaved tenant with the zoo's
    ``RandomScan`` (fresh uniform draws every iteration — it faults on
    nearly every access and, in the shared pool, evicts its neighbour's
    blocks at will).  Three treatments per scenario:

    * ``solo``     — the well-behaved tenant alone at the same oversub
      (its no-neighbour reference);
    * ``shared``   — the PR 5 mux over the merge, one global capacity pool;
    * ``budgeted`` — the same mux with a :class:`~repro.uvm.api.specs.QosSpec`
      (well-behaved floor 0.5, scanner floor 0.05): the controller learns
      the scanner is unstable, squeezes its budget toward the floor, and
      the leading victim key evicts the scanner's over-budget blocks
      before ANY under-budget block.

    Per-tenant IPC uses the repo's timing model on per-tenant attributed
    counters (faults/thrash by triggering access; migrations approximated
    by demand faults — prefetch transfers overlap compute and vanish in
    the ``max(mig - base, 0)`` term at this scale).  ``ipc_spread`` is
    max/min across tenants — 1.0 = perfectly fair.

    The headline assertions (the ISSUE 9 acceptance): under budgets, the
    well-behaved tenant's pages-thrashed is (a) no worse than its
    standalone run and (b) no worse than the shared pool gave it.
    Geometry is quick-PINNED like table9 (scale 0.4, quick predictor,
    group 256) so the committed BENCH_sim.json ``qos`` section stays
    byte-stable."""
    import json

    from repro.configs.predictor_paper import CONFIG_QUICK
    from repro.uvm import runtime as R
    from repro.uvm import timing
    from repro.uvm import trace as T
    from repro.uvm import zoo as Z
    from repro.uvm.api.specs import QosSpec, QosTierSpec, TrainSpec

    t0 = time.time()
    SCALE, CAP, GROUP = 0.4, 3000, 256
    tcfg = TrainSpec(group_size=GROUP, epochs=2, batch_size=128).to_train_config()
    qos = lambda good: QosSpec(tiers=(
        QosTierSpec(good, floor=0.5, share=1.0),
        QosTierSpec("RandomScan", floor=0.05, share=1.0),
    ))

    def cut(tr):
        return tr.slice(0, min(len(tr), CAP))

    def tenant_ipc(res, stats):
        # per-tenant timing-model IPC from attributed counters (see above)
        return timing.ipc(
            {"faults": stats["faults"], "pages_thrashed": stats["pages_thrashed"],
             "migrated_blocks": stats["faults"], "zero_copy": 0},
            stats["accesses"],
        )

    rows, checks = [], []
    # per-scenario oversubscription picked where the shared pool visibly
    # hurts the well-behaved tenant (pressure high enough that RandomScan's
    # evictions land on the neighbour) — part of the quick pin
    for good, oversub in (("StreamTriad", 2.5), ("Hotspot", 1.6)):
        solo_tr = cut(T.get_trace(good, scale=SCALE))
        merged = T.concurrent(
            [cut(T.get_trace(good, scale=SCALE)), cut(Z.get_trace("RandomScan", scale=SCALE))],
            seed=0, slice_len=GROUP,
        )
        solo = R.run_ours(solo_tr, CONFIG_QUICK, tcfg, oversubscription=oversub)
        shared = R.run_ours(merged, CONFIG_QUICK, tcfg, oversubscription=oversub)
        budgeted = R.run_ours(merged, CONFIG_QUICK, tcfg, oversubscription=oversub,
                              qos=qos(good))
        for name, res in (("shared", shared), ("budgeted", budgeted)):
            pts = res.per_tenant_stats
            g, s = pts["0"], pts["1"]  # concurrent() order: good first
            ipc_g, ipc_s = tenant_ipc(res, g), tenant_ipc(res, s)
            rows.append({
                "scenario": f"{good}+RandomScan",
                "oversub": oversub,
                "pool": name,
                "good_thrash": g["pages_thrashed"],
                "scan_thrash": s["pages_thrashed"],
                "solo_thrash": solo.stats["pages_thrashed"],
                "good_ipc": round(ipc_g, 4),
                "scan_ipc": round(ipc_s, 4),
                "ipc_spread": round(max(ipc_g, ipc_s) / max(min(ipc_g, ipc_s), 1e-9), 3),
                "budgets": res.budgets or "",
            })
        checks.append({
            "scenario": f"{good}+RandomScan",
            "solo": solo.stats["pages_thrashed"],
            "shared": shared.per_tenant_stats["0"]["pages_thrashed"],
            "budgeted": budgeted.per_tenant_stats["0"]["pages_thrashed"],
        })
    emit("table10_qos_fairness", rows, t0)
    # THE fairness claim: budgets keep the well-behaved tenant whole under
    # a thrashing neighbour — no worse than standalone, and never worse
    # than the shared pool gave it
    for c in checks:
        assert c["budgeted"] <= c["solo"], (c, rows)
        assert c["budgeted"] <= c["shared"], (c, rows)
    bench = _bench_ledger()
    data = json.loads(bench.read_text())
    data["qos"] = {
        "benchmark": "PYTHONPATH=src python -m benchmarks.run --only table10",
        "headline": {
            "well_behaved_thrash": {c["scenario"]: {k: c[k] for k in ("solo", "shared", "budgeted")}
                                    for c in checks},
            "notes": "budgeted mux (floors 0.5/0.05, percentile stability) vs "
                     "shared pool under an adversarial RandomScan co-tenant, "
                     "quick-pinned geometry; asserted in-benchmark: budgeted "
                     "<= solo and budgeted <= shared for the well-behaved tenant",
        },
        "rows": rows,
    }
    bench.write_text(json.dumps(data, indent=2) + "\n")
    return rows
