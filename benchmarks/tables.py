"""Paper tables: I (strategies w/o prefetch vs upper bound), II (HPE x
prefetcher interplay), IV (predictor footprint), VI (full strategy matrix),
VII (concurrent multi-workload accuracy), VIII (Section V-F concurrent
top-1 through the full runtime: TenantMux vs merged-single-manager),
IX (drift: re-classifying vs frozen-pattern managers on phase-changing zoo
traces — a subsystem result beyond the paper's tables)."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import ALL_BENCH, emit
from repro.uvm.api import Session


def table1(ctx: Session):
    """Baseline / D.+HPE / UVMSmart / D.+Belady pages thrashed @125%."""
    t0 = time.time()
    ctx.uvmsmart_many(ctx.benches)  # independent runs overlap on the host
    rows = []
    for b in ctx.benches:
        rows.append({
            "benchmark": b,
            "baseline": ctx.sim(b, "lru", "tree")["pages_thrashed"],
            "d_hpe": ctx.sim(b, "hpe", "demand")["pages_thrashed"],
            "uvmsmart": ctx.uvmsmart(b)["pages_thrashed"],
            "d_belady": ctx.sim(b, "belady", "demand")["pages_thrashed"],
        })
    emit("table1_thrashing", rows, t0)
    # the paper's structural claims
    for r in rows:
        assert r["d_belady"] <= r["d_hpe"] + 1e-9, r
    return rows


def table2(ctx: Session):
    """Demand.+HPE vs Tree.+HPE (the interplay collapse)."""
    t0 = time.time()
    rows = []
    for b in ctx.benches:
        d = ctx.sim(b, "hpe", "demand")["pages_thrashed"]
        t = ctx.sim(b, "hpe", "tree")["pages_thrashed"]
        rows.append({"benchmark": b, "demand_hpe": d, "tree_hpe": t, "derived": f"collapse_x{t / max(d, 1):.0f}"})
    emit("table2_hpe_prefetch", rows, t0)
    return rows


def table3(ctx: Session):
    """Unique page deltas per program phase (the growing-class problem that
    motivates incremental learning; paper Table III)."""
    from repro.core.features import unique_deltas_per_phase

    t0 = time.time()
    rows = []
    for b in ctx.benches:
        p = unique_deltas_per_phase(ctx.trace(b), 3)
        rows.append({
            "benchmark": b, "phase0": p[0], "phase1": p[1], "phase2": p[2],
            "derived": f"growth_x{p[2] / max(p[0], 1):.1f}",
        })
    emit("table3_delta_growth", rows, t0)
    # NW / Srad must grow; streaming must stay flat (paper's central premise)
    by = {r["benchmark"]: r for r in rows}
    assert by["NW"]["phase2"] > by["NW"]["phase0"]
    assert by["StreamTriad"]["phase2"] <= by["StreamTriad"]["phase0"] + 2
    return rows


def table4(ctx: Session):
    """Predictor memory footprint with the paper's accounting (Eq. 4):
    Total = (Params*2 + Activations) * Patterns, 4-bit-ish quantised."""
    t0 = time.time()
    from repro.core.predictor import param_count

    rows = []
    n_params = param_count(ctx.pcfg)
    params_mb = n_params * 4 / 2**20  # fp32
    acti_mb = 1.46  # measured activation budget from the paper's Table IV
    for b in ctx.benches:
        from repro.core.pattern import PatternClassifier

        tr = ctx.trace(b)
        c = PatternClassifier()
        pats = set()
        G = ctx.tcfg.group_size
        for lo in range(0, len(tr), G):
            pats.add(c.classify(tr.block[lo : lo + G], tr.kernel[lo : lo + G]))
        total = (params_mb * 2 + acti_mb) * len(pats)
        rows.append({
            "benchmark": b, "params_mb": round(params_mb, 2), "acti_mb": acti_mb,
            "patterns": len(pats), "total_mb": round(total, 2),
        })
    emit("table4_footprint", rows, t0)
    return rows


def table6(ctx: Session):
    """Full strategy matrix incl. our solution (the headline table)."""
    t0 = time.time()
    ctx.ours_many(ctx.benches)  # independent learned runs overlap on the host
    rows = []
    reductions = []
    for b in ctx.benches:
        base = ctx.sim(b, "lru", "tree")["pages_thrashed"]
        ours = ctx.ours(b).stats["pages_thrashed"]
        smart = ctx.uvmsmart(b)["pages_thrashed"]
        rows.append({
            "benchmark": b,
            "baseline": base,
            "tree_hpe": ctx.sim(b, "hpe", "tree")["pages_thrashed"],
            "uvmsmart": smart,
            "ours": ours,
            "demand_hpe": ctx.sim(b, "hpe", "demand")["pages_thrashed"],
            "demand_belady": ctx.sim(b, "belady", "demand")["pages_thrashed"],
        })
        if base > 0:
            reductions.append(1 - ours / base)
    avg_red = float(np.mean(reductions)) if reductions else 0.0
    rows.insert(0, {"benchmark": "AVG_REDUCTION_VS_BASELINE", "baseline": "", "tree_hpe": "",
                    "uvmsmart": "", "ours": round(avg_red, 3), "demand_hpe": "", "demand_belady": "",
                    })
    emit("table6_thrashing_full", rows, t0)
    return rows


def table7(ctx: Session):
    """Concurrent multi-workload page-delta prediction (scalability).
    'Ours' follows the paper's Section V-A protocol: per-pattern models
    pretrained on a (different-input) corpus, then fine-tuned online."""
    import dataclasses

    t0 = time.time()
    pretrain = dataclasses.replace(ctx.default_pretrain, seed0=321)
    pairs = [("StreamTriad", "2DCONV"), ("Hotspot", "Srad-v2"), ("NW", "2DCONV"), ("ATAX", "Srad-v2")]
    rows = []
    for a, b in pairs:
        # slices aligned with the training group size: each group sees ONE
        # tenant's coherent stream, which is what the DFA classifies (per-access
        # mixing would blend pattern classes inside every group)
        w = ctx.concurrent((a, b), slice_len=ctx.tcfg.group_size)
        online = ctx.protocol(w, "online_single")
        ours = ctx.protocol(w, "ours", pretrain=pretrain)
        rows.append({
            "workloads": f"{a}+{b}", "online_top1": round(online.top1, 3),
            "ours_top1": round(ours.top1, 3), "derived": f"delta={ours.top1 - online.top1:+.3f}",
        })
    emit("table7_multiworkload", rows, t0)
    return rows


def table8(ctx: Session):
    """Section V-F concurrent top-1 through the FULL runtime (simulator in
    the loop): the multi-tenant `TenantMux` (one classifier->predictor
    pipeline per tenant, isolated frequency tables) against the
    merged-single-manager baseline that treats the interleaved stream as
    one workload.  The paper reports per-workload specialization is worth
    +10.2% top-1 on average (up to +30.2%); both columns run the Section
    V-A pretrain-then-finetune protocol over the same tenant-tagged
    merge."""
    t0 = time.time()
    pairs = [("StreamTriad", "2DCONV"), ("Hotspot", "Srad-v2"), ("NW", "2DCONV"), ("ATAX", "Srad-v2")]
    rows, deltas = [], []
    for a, b in pairs:
        # group-aligned scheduler slices, like table7: each observed batch
        # is ONE tenant's coherent stream, which is what the DFA classifies
        w = ctx.concurrent((a, b), slice_len=ctx.tcfg.group_size)
        mux = ctx.ours(w)  # ModelSpec.tenancy defaults to 'mux'
        merged = ctx.ours(w, tenancy="merged")
        per = {k: round(v, 3) for k, v in sorted((mux.per_tenant_top1 or {}).items())}
        rows.append({
            "workloads": f"{a}+{b}",
            "merged_top1": round(merged.top1, 3),
            "mux_top1": round(mux.top1, 3),
            "tenant0_top1": per.get("0", ""),
            "tenant1_top1": per.get("1", ""),
            "derived": f"delta={mux.top1 - merged.top1:+.3f}",
        })
        deltas.append(mux.top1 - merged.top1)
    avg = float(np.mean(deltas)) if deltas else 0.0
    rows.insert(0, {
        "workloads": "AVG_MUX_GAIN", "merged_top1": "", "mux_top1": "",
        "tenant0_top1": "", "tenant1_top1": "", "derived": f"delta={avg:+.3f}",
    })
    emit("table8_concurrent_mux", rows, t0)
    # the acceptance pin: per-tenant specialization must not lose to the
    # merged baseline on the Section V-F suite
    assert avg >= 0, rows
    return rows


def table9(ctx: Session):
    """Drift benchmark: streaming re-classification measured as a subsystem
    result on the zoo's phase-changing traces (benchmarks beyond the paper's
    tables; see docs/REPRODUCING.md).

    Each trace alternates a learnable streaming phase (StreamTriad) with the
    zoo's RandomScan noise phase (fresh uniform draws — unmemorizable).  A
    FROZEN-pattern manager (``reclass_interval`` so large the seed window
    never expires) funnels every phase into the pattern classified first, so
    the noise phases train straight into the streaming model; re-classifying
    managers (``reclass_interval=256/512``, hysteresis 2) quarantine the
    noise in the RANDOM entry and return to a warm, unpolluted model at each
    switch-back.  The rule-based ``hpe+tree`` column is the no-learning
    floor.  The headline assertion: the 256-fault re-classifier beats frozen
    on top-1 AND pages-thrashed on every row (strictly on average).

    The geometry is PINNED to quick scale (trace scale 0.4, the quick
    predictor, group 256) regardless of ``--scale`` — this is a subsystem
    pin like the golden suite, not a paper-scale table, and pinning keeps
    the committed BENCH_sim.json ``drift`` section byte-stable.  Rows are
    recorded into BENCH_sim.json (deterministic content only)."""
    import json
    from pathlib import Path

    from benchmarks.common import PCFG_QUICK
    from repro.uvm import runtime as R
    from repro.uvm.api.specs import PretrainSpec, TrainSpec

    t0 = time.time()
    FROZEN = 1 << 30  # seed window never expires: the frozen-pattern manager
    train = TrainSpec(group_size=256, epochs=2, batch_size=128)
    tcfg = train.to_train_config()
    pretrain = PretrainSpec(scale=0.24)  # quick Session.default_pretrain
    table = lambda: ctx.pretrained(pretrain, pcfg=PCFG_QUICK, train=train)

    def learned(tr, oversub, **kw):
        mgr = R.manager_for(tr, PCFG_QUICK, tcfg, oversubscription=oversub,
                            table=table(), **kw)
        res = R.run_ours(tr, PCFG_QUICK, tcfg, oversubscription=oversub, manager=mgr)
        return res, mgr.n_pattern_switches

    cycle = ("StreamTriad", "RandomScan")
    suite = [  # (drifting workload, oversubscription)
        (ctx.drifting(cycle + ("StreamTriad",), scale=0.4, cap=6000, segment=1024), 1.25),
        (ctx.drifting(cycle * 2 + ("StreamTriad",), scale=0.4, cap=6400, segment=1280), 1.2),
        (ctx.drifting(cycle * 2 + ("StreamTriad",), scale=0.4, cap=6000, segment=1024), 1.3),
    ]
    rows, d_top1, d_thrash = [], [], []
    for w, oversub in suite:
        tr = ctx.trace(w)
        froz, _ = learned(tr, oversub, reclass_interval=FROZEN)
        r256, switches = learned(tr, oversub, reclass_interval=256, reclass_hysteresis=2)
        r512, _ = learned(tr, oversub, reclass_interval=512, reclass_hysteresis=2)
        rule = ctx.sim(w, "hpe", "tree", oversub)
        rows.append({
            "trace": tr.name.replace("drift:", ""),
            "oversub": oversub,
            "frozen_top1": round(froz.top1, 3),
            "frozen_thrash": froz.stats["pages_thrashed"],
            "reclass256_top1": round(r256.top1, 3),
            "reclass256_thrash": r256.stats["pages_thrashed"],
            "switches": switches,
            "reclass512_top1": round(r512.top1, 3),
            "reclass512_thrash": r512.stats["pages_thrashed"],
            "rule_thrash": rule["pages_thrashed"],
            "derived": f"dtop1={r256.top1 - froz.top1:+.3f}",
        })
        d_top1.append(r256.top1 - froz.top1)
        d_thrash.append(r256.stats["pages_thrashed"] - froz.stats["pages_thrashed"])
        # re-classification must actually fire (noise in, noise out, back):
        # >= 2 switches per trace, and the learned manager must stay far
        # below the no-learning floor on thrashing
        assert switches >= 2, rows
        assert r256.stats["pages_thrashed"] < rule["pages_thrashed"], rows
    avg_t1, avg_thr = float(np.mean(d_top1)), float(np.mean(d_thrash))
    rows.insert(0, {
        "trace": "AVG_RECLASS_VS_FROZEN", "oversub": "", "frozen_top1": "",
        "frozen_thrash": "", "reclass256_top1": "", "reclass256_thrash": "",
        "switches": "", "reclass512_top1": "", "reclass512_thrash": "",
        "rule_thrash": "", "derived": f"dtop1={avg_t1:+.3f} dthrash={avg_thr:+.0f}",
    })
    emit("table9_drift_reclass", rows, t0)
    # THE drift claim: periodic re-classification beats the frozen-pattern
    # manager on BOTH metrics — never worse on any phase-changing trace,
    # strictly better on average
    assert all(d >= 0 for d in d_top1) and avg_t1 > 0, rows
    assert all(d <= 0 for d in d_thrash) and avg_thr < 0, rows
    # record the subsystem result (deterministic content only) into the
    # committed benchmark ledger
    bench = Path(__file__).resolve().parent.parent / "BENCH_sim.json"
    data = json.loads(bench.read_text())
    data["drift"] = {
        "benchmark": "PYTHONPATH=src python -m benchmarks.run --only table9",
        "headline": {
            "avg_top1_delta_reclass256_vs_frozen": round(avg_t1, 4),
            "avg_pages_thrashed_delta": round(avg_thr, 1),
            "notes": "re-classifying manager (interval 256, hysteresis 2) vs "
                     "frozen-pattern manager on phase-changing zoo traces "
                     "(StreamTriad x RandomScan cycles), quick-pinned geometry; "
                     "interval 512 is too coarse to switch on the 1024-access "
                     "phases and collapses onto the frozen manager",
        },
        "rows": rows,
    }
    bench.write_text(json.dumps(data, indent=2) + "\n")
    return rows
