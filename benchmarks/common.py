"""Shared harness for the per-table/figure benchmarks — now a thin layer
over :mod:`repro.uvm.api`.

The session/caching logic that used to live here moved into
:class:`repro.uvm.api.Session`, which additionally persists every computed
cell in the content-addressed run store under ``experiments/runs/`` —
rerunning a table after a crash (or after the CLI already swept the same
cells) recomputes nothing.  (The deprecated ``Ctx`` shim that bridged the
historical constructor signature completed its removal schedule and is
gone; construct a :class:`Session` directly.)

`--scale quick` (default) runs reduced traces on CPU in minutes;
`--scale paper` uses the full generator sizes.
"""
from __future__ import annotations

import csv
import time
from pathlib import Path

# importing the API configures the persistent XLA compile cache
# (repro.uvm.api.session.enable_compile_cache) before any jit runs
from repro.uvm.api import ALL_BENCH, FEATURED, Session  # noqa: F401

# Deprecated re-exports (PR 3 moved the configs to repro.configs.predictor_paper;
# in-tree call sites migrated in PR 10): accessing them warns DeprecationWarning,
# and the names are DELETED in the next PR — see docs/API.md for the schedule.
_DEPRECATED_CONFIGS = {"PCFG_QUICK": "CONFIG_QUICK", "PCFG_FULL": "CONFIG"}


def __getattr__(name: str):
    if name in _DEPRECATED_CONFIGS:
        import warnings

        from repro.configs import predictor_paper

        new = _DEPRECATED_CONFIGS[name]
        warnings.warn(
            f"benchmarks.common.{name} is deprecated and will be removed in the "
            f"next PR; import repro.configs.predictor_paper.{new} instead",
            DeprecationWarning,
            stacklevel=2,
        )
        return getattr(predictor_paper, new)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


OUT_DIR = Path("experiments/bench")


def emit(name: str, rows: list[dict], t0: float) -> None:
    """Write CSV + print the `name,us_per_call,derived` contract line."""
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    path = OUT_DIR / f"{name}.csv"
    if rows:
        with path.open("w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
            w.writeheader()
            w.writerows(rows)
    us = (time.time() - t0) * 1e6 / max(len(rows), 1)
    derived = rows[0].get("derived", "") if rows else ""
    print(f"{name},{us:.0f},{derived}")
    for r in rows:
        print("   ", {k: (round(v, 4) if isinstance(v, float) else v) for k, v in r.items()})
