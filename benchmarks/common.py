"""Shared context for the per-table/figure benchmark harnesses.

Results are cached per (benchmark, strategy, oversubscription) so the tables
and figures that reuse the same runs (Table VI, Figs. 13/14) don't recompute
the learned runtime. `--scale quick` (default) runs reduced traces on CPU in
minutes; `--scale paper` uses the full generator sizes.
"""
from __future__ import annotations

import csv
import dataclasses
import os
import time
from pathlib import Path

import jax
import numpy as np

# Persistent XLA compilation cache: the simulator's unified scan and the
# predictor's train/eval jits compile once per (shape-bucket) ever, not once
# per process. Harmless if the dir is unwritable (JAX falls back silently).
_CACHE_DIR = os.environ.get("REPRO_JAX_CACHE", str(Path.home() / ".cache" / "repro_jax"))
try:
    jax.config.update("jax_compilation_cache_dir", _CACHE_DIR)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.3)
except Exception:
    pass

from repro.configs.predictor_paper import CONFIG as PCFG_FULL
from repro.configs.predictor_paper import PredictorConfig

# Quick-scale predictor: small enough for CPU minutes, but with a delta
# vocabulary that does NOT alias the benchmarks' delta sets (the smoke
# config's 32-entry vocab hash-collides NW's hundreds of deltas into noise).
PCFG_QUICK = PredictorConfig(
    name="predictor-quick", d_model=32, num_heads=2, num_layers=1, d_ff=64,
    page_vocab=2048, delta_vocab=512, pc_vocab=64, tb_vocab=64,
)
from repro.core.incremental import RunResult, TrainConfig, run_protocol
from repro.uvm import runtime as R
from repro.uvm import simulator as S
from repro.uvm import timing
from repro.uvm import trace as T
from repro.uvm.uvmsmart import run_uvmsmart

OUT_DIR = Path("experiments/bench")

ALL_BENCH = list(T.BENCHMARKS)
FEATURED = ["ATAX", "BICG", "Hotspot", "NW", "Srad-v2"]  # the paper's focus set


@dataclasses.dataclass
class Ctx:
    scale: float = 0.4
    cap: int = 6000  # max trace length (quick mode)
    pcfg: object = PCFG_QUICK
    tcfg: TrainConfig = dataclasses.field(default_factory=lambda: TrainConfig(group_size=1024, epochs=2, batch_size=128))
    benches: list = dataclasses.field(default_factory=lambda: list(ALL_BENCH))

    def __post_init__(self):
        self._traces: dict = {}
        self._sims: dict = {}
        self._ours: dict = {}
        self._smart: dict = {}
        self._proto: dict = {}

    @classmethod
    def paper(cls):
        return cls(scale=1.0, cap=60_000, pcfg=PCFG_FULL, tcfg=TrainConfig(group_size=2048, epochs=3, batch_size=256))

    def trace(self, name: str) -> T.Trace:
        if name not in self._traces:
            tr = T.get_trace(name, scale=self.scale)
            self._traces[name] = tr.slice(0, min(len(tr), self.cap))
        return self._traces[name]

    # Every rule-based cell the tables/figures touch; computed together so one
    # vmapped scan per (benchmark, oversubscription) fills the whole cache row.
    STANDARD_CELLS = (
        ("lru", "tree"), ("lru", "demand"), ("hpe", "demand"),
        ("hpe", "tree"), ("belady", "demand"),
    )

    def sims(self, name: str, cells: list) -> list[dict]:
        """Batched sweep: (policy, prefetch, oversub) cells in ONE vmapped
        scan (bit-identical to per-cell S.run for non-random policies)."""
        missing = [c for c in cells if (name, *c) not in self._sims]
        if missing:
            for c, st in zip(missing, S.run_batch(self.trace(name), missing)):
                self._sims[(name, *c)] = st
        return [self._sims[(name, *c)] for c in cells]

    def sim(self, name: str, policy: str, prefetch: str, oversub: float = 1.25) -> dict:
        key = (name, policy, prefetch, oversub)
        if key not in self._sims:
            cells = [(p, f, oversub) for p, f in self.STANDARD_CELLS]
            if (policy, prefetch, oversub) not in cells:
                cells.append((policy, prefetch, oversub))
            self.sims(name, cells)
        return self._sims[key]

    def pretrained(self):
        """Paper Section V-A: a per-pattern table pretrained on a corpus of
        5 benchmarks with different inputs; cloned per run (fine-tuning
        mutates the entries)."""
        if not hasattr(self, "_pretrained"):
            corpus = [T.BENCHMARKS[n](scale=self.scale * 0.6, seed=777 + i) for i, n in enumerate(["ATAX", "Backprop", "BICG", "Hotspot", "NW"])]
            self._pretrained = R.pretrain_table(corpus, self.pcfg, self.tcfg, max_rounds=2)
        return self._pretrained.clone()

    def ours(self, name: str, oversub: float = 1.25, **kw) -> R.LearnedRunResult:
        key = (name, oversub, tuple(sorted(kw.items())))
        if key not in self._ours:
            self._ours[key] = R.run_ours(
                self.trace(name), self.pcfg, self.tcfg, oversubscription=oversub,
                table=self.pretrained(), **kw,
            )
        return self._ours[key]

    @staticmethod
    def _warm_many(run_one, todo: list) -> None:
        """Run one item serially (so the pool hits warm compiles), then the
        rest through a small thread pool. Each item is a self-contained
        computation, so results are identical to the serial path regardless
        of scheduling; JAX releases the GIL during compiled execution and
        the slight oversubscription hides host<->device sync stalls."""
        from concurrent.futures import ThreadPoolExecutor

        if todo:
            run_one(todo[0])
        if len(todo) <= 1:
            return
        with ThreadPoolExecutor(max_workers=min(4, 2 * (os.cpu_count() or 1))) as pool:
            list(pool.map(run_one, todo[1:]))

    def ours_many(self, names: list, oversub: float = 1.25, **kw) -> None:
        """Warm the `ours` cache for many benchmarks.

        Two engines, picked adaptively:

        * `R.run_ours_many` — every benchmark in lockstep, vmapping
          predict/train/simulate across lanes (each lane still clones the
          pretrained table and owns its freq table / classifier / simulator
          state, so results match per-benchmark runs), with the lane axis
          sharded across devices.  The default whenever >1 device is
          visible; force with REPRO_OURS_BATCHED=1.
        * thread-pooled serial runs — the default on a single device, where
          the batched engine's extra per-process jit traces cost more than
          its one-dispatch-per-stage saves (see BENCH_sim.json).  Force
          with REPRO_OURS_BATCHED=0.
        """
        self.pretrained()  # build (or load) the shared table once, serially
        todo = [n for n in names if (n, oversub, tuple(sorted(kw.items()))) not in self._ours]
        if not todo:
            return
        knob = os.environ.get("REPRO_OURS_BATCHED", "")
        batched = len(todo) > 1 and knob != "0" and (knob == "1" or len(jax.devices()) > 1)
        if not batched:
            self._warm_many(lambda n: self.ours(n, oversub, **kw), todo)
            return
        results = R.run_ours_many(
            [self.trace(n) for n in todo], self.pcfg, self.tcfg,
            oversubscription=oversub, tables=[self.pretrained() for _ in todo], **kw,
        )
        for n, res in zip(todo, results):
            self._ours[(n, oversub, tuple(sorted(kw.items())))] = res

    def uvmsmart_many(self, names: list, oversub: float = 1.25) -> None:
        """Warm the UVMSmart cache concurrently (independent runs)."""
        self._warm_many(
            lambda n: self.uvmsmart(n, oversub),
            [n for n in names if (n, oversub) not in self._smart],
        )

    def uvmsmart(self, name: str, oversub: float = 1.25) -> dict:
        key = (name, oversub)
        if key not in self._smart:
            self._smart[key] = run_uvmsmart(self.trace(name), oversubscription=oversub)
        return self._smart[key]

    def protocol(self, name: str, mode: str, kind: str = "transformer") -> RunResult:
        key = (name, mode, kind)
        if key not in self._proto:
            self._proto[key] = run_protocol(self.trace(name), self.pcfg, self.tcfg, mode=mode, kind=kind)
        return self._proto[key]

    def ipc(self, name: str, stats: dict, **kw) -> float:
        return timing.ipc(stats, len(self.trace(name)), **kw)


def emit(name: str, rows: list[dict], t0: float) -> None:
    """Write CSV + print the `name,us_per_call,derived` contract line."""
    OUT_DIR.mkdir(parents=True, exist_ok=True)
    path = OUT_DIR / f"{name}.csv"
    if rows:
        with path.open("w", newline="") as f:
            w = csv.DictWriter(f, fieldnames=list(rows[0].keys()))
            w.writeheader()
            w.writerows(rows)
    us = (time.time() - t0) * 1e6 / max(len(rows), 1)
    derived = rows[0].get("derived", "") if rows else ""
    print(f"{name},{us:.0f},{derived}")
    for r in rows:
        print("   ", {k: (round(v, 4) if isinstance(v, float) else v) for k, v in r.items()})
