"""Fail CI when the documented commands drift from the real entry points.

Checks, without running any benchmark:
  * every ``python -m <module>`` mentioned in docs/REPRODUCING.md,
    docs/API.md and README.md answers ``--help`` (argparse wiring exists),
  * every ``--flag`` a doc attaches to a module appears in that module's
    ``--help`` output (for ``repro.uvm.cli``, in the documented
    SUBCOMMAND's own ``--help``),
  * every ``python -m repro.uvm.cli <subcommand>`` names a real key of its
    SUBCOMMANDS registry, and every SUBCOMMANDS key is documented in at
    least one of the scanned docs (a new subcommand must ship with docs),
  * every ``--only <target>`` mentioned for benchmarks.run is a real key of
    its SUITES registry,
  * every repo-relative path the docs reference exists.

    PYTHONPATH=src python scripts/check_docs.py
"""
from __future__ import annotations

import re
import subprocess
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
DOCS = [ROOT / "docs" / "REPRODUCING.md", ROOT / "docs" / "API.md", ROOT / "README.md"]

#: modules whose first positional doc token is a subcommand with its own help
SUBCOMMAND_MODULES = {"repro.uvm.cli"}

#: JSONL/protocol fields that must stay documented on BOTH sides: in the
#: subcommand's own --help AND in at least one scanned doc (a field the
#: code grows without docs — or docs promise without code — is drift)
REQUIRED_FIELD_MENTIONS = {
    ("repro.uvm.cli", "serve"): ("tenant", "health", "fallback", "pattern", "budget"),
}

#: flags that must stay documented on BOTH sides too: the fault-tolerance
#: serve surface (PR 6) and the drift-replay surface (PR 7) ship with docs
#: or CI fails
REQUIRED_FLAG_MENTIONS = {
    ("repro.uvm.cli", "serve"): (
        "--checkpoint-dir", "--checkpoint-every", "--resume", "--inject",
        "--latency-budget-ms", "--reclass-interval", "--reclass-hysteresis",
        # the QoS surface (PR 9): budgeted capacity partitioning
        "--qos-tier", "--qos-stability", "--qos-interval",
    ),
    ("repro.uvm.cli", "export"): (
        "--phases", "--drift-kind", "--switch", "--mix-window", "--joins",
        "--spans", "--out",
    ),
    # the async serving surface (PR 8): server + loadgen ship with docs
    ("repro.uvm.cli", "server"): (
        "--socket", "--port", "--max-sessions", "--idle-timeout",
        "--gather-spins", "--serial", "--engine", "--aot-cache",
        "--qos-tier", "--qos-stability", "--qos-interval",
    ),
    ("repro.uvm.cli", "loadgen"): (
        "--connect", "--clients", "--rate", "--repeat", "--hello-prefix",
        "--malformed-every", "--malformed-client", "--inject",
        "--chaos-client", "--json",
    ),
    # the Pallas kernel section (PR 10): the benchmark flag ships with docs
    ("benchmarks.sim_perf", None): ("--kernels", "--manager", "--smoke", "--update-baseline"),
}

# python -m <module> [args ...] — up to a backtick, pipe or line end
CMD_RE = re.compile(r"python (?:-m (?P<mod>[\w\.]+)|(?P<script>[\w\./]+\.py))(?P<args>[^`|\n]*)")
PATH_RE = re.compile(r"\b(?:src|tests|docs|examples|experiments|benchmarks|scripts)/[\w\./-]+")


def run_help(module: str, subcommand: str | None = None) -> str:
    cmd = [sys.executable, "-m", module] + ([subcommand] if subcommand else []) + ["--help"]
    out = subprocess.run(
        cmd, capture_output=True, text=True, cwd=ROOT, timeout=240,
        env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin:/usr/local/bin", "JAX_PLATFORMS": "cpu", "HOME": str(Path.home())},
    )
    label = f"{module} {subcommand}" if subcommand else module
    assert out.returncode == 0, f"`python -m {label} --help` failed:\n{out.stderr[-2000:]}"
    return out.stdout


def main() -> int:
    failures = []
    helps: dict[str, str] = {}
    cmds = []
    seen_subcommands: set[str] = set()
    for doc in DOCS:
        text = doc.read_text()
        cmds += [(doc.name, m) for m in CMD_RE.finditer(text)]
        for p in PATH_RE.findall(text):
            if not (ROOT / p.rstrip(".")).exists():
                failures.append(f"{doc.name}: referenced path does not exist: {p}")

    for doc_name, m in cmds:
        mod, script, args = m.group("mod"), m.group("script"), m.group("args") or ""
        if script:
            if not (ROOT / script).exists():
                failures.append(f"{doc_name}: script does not exist: {script}")
            continue
        sub = None
        if mod in SUBCOMMAND_MODULES:
            # the first bare token after the module is its subcommand; it
            # must be a key of the module's SUBCOMMANDS registry and its
            # OWN --help is what the documented flags are checked against
            sys.path[:0] = [str(ROOT), str(ROOT / "src")]
            from repro.uvm.cli import SUBCOMMANDS  # noqa: PLC0415

            tok = re.match(r"\s*(\{?[\w,]+\}?)", args)
            subs = [x for x in (tok.group(1) if tok else "").strip("{}").split(",") if x]
            if not subs:
                continue  # a bare `python -m repro.uvm.cli` mention
            bad = [x for x in subs if x not in SUBCOMMANDS]
            if bad:
                failures.append(f"{doc_name}: {bad} not repro.uvm.cli subcommands ({m.group(0).strip()!r})")
                continue
            seen_subcommands.update(subs)
            sub = subs[0]
            args = args[tok.end():]
        key = (mod, sub)
        if key not in helps:
            try:
                helps[key] = run_help(mod, sub)
            except AssertionError as e:
                failures.append(f"{doc_name}: {e}")
                helps[key] = ""
                continue
        for flag in re.findall(r"--[\w-]+", args):
            if flag not in helps[key]:
                failures.append(f"{doc_name}: `{flag}` not in `python -m {mod}{' ' + sub if sub else ''} --help` ({m.group(0).strip()!r})")
        if mod == "benchmarks.run":
            sys.path[:0] = [str(ROOT), str(ROOT / "src")]
            from benchmarks.run import SUITES  # noqa: PLC0415

            only = re.search(r"--only((?:\s+[\w]+)+)", args)
            for target in (only.group(1).split() if only else []):
                if target not in SUITES:
                    failures.append(f"{doc_name}: `--only {target}` is not a benchmarks.run suite")

    # protocol-field direction: the serve sidecar's JSONL "tenant" field
    # (and any future required field) must appear in the subcommand's own
    # --help AND in the scanned docs
    all_docs_text = "".join(d.read_text() for d in DOCS)
    for (mod, sub), fields in REQUIRED_FIELD_MENTIONS.items():
        key = (mod, sub)
        if key not in helps:
            try:
                helps[key] = run_help(mod, sub)
            except AssertionError as e:
                failures.append(str(e))
                helps[key] = ""
        for field in fields:
            if field not in helps[key]:
                failures.append(f"`{field}` field undocumented in `python -m {mod} {sub} --help`")
            if f'"{field}"' not in all_docs_text:
                failures.append(f'the `"{field}"` {sub} line field is documented in none of '
                                f"{[d.name for d in DOCS]}")

    # flag direction: each required flag must exist in the subcommand's
    # --help AND be mentioned in at least one scanned doc
    for (mod, sub), flags in REQUIRED_FLAG_MENTIONS.items():
        key = (mod, sub)
        if key not in helps:
            try:
                helps[key] = run_help(mod, sub)
            except AssertionError as e:
                failures.append(str(e))
                helps[key] = ""
        label = f"{mod} {sub}" if sub else mod
        for flag in flags:
            if flag not in helps[key]:
                failures.append(f"`{flag}` missing from `python -m {label} --help`")
            if flag not in all_docs_text:
                failures.append(f"`{flag}` ({label}) is documented in none of {[d.name for d in DOCS]}")

    # env-knob direction (PR 10): the kernel fast path's switch must stay
    # documented in the scanned docs AND implemented by the simulator —
    # docs promising a knob the code dropped (or vice versa) is drift
    sys.path[:0] = [str(ROOT), str(ROOT / "src")]
    from repro.uvm import simulator as _sim  # noqa: PLC0415

    if "REPRO_SIM_KERNELS" not in all_docs_text:
        failures.append(f"`REPRO_SIM_KERNELS` is documented in none of {[d.name for d in DOCS]}")
    if not (hasattr(_sim, "sim_kernels_enabled") and "REPRO_SIM_KERNELS" in (_sim.__doc__ or "")):
        failures.append("repro.uvm.simulator no longer implements/documents REPRO_SIM_KERNELS")

    # coverage direction: a subcommand added to the CLI without a documented
    # invocation is drift too (serve/run/sweep/report must all appear)
    sys.path[:0] = [str(ROOT), str(ROOT / "src")]
    from repro.uvm.cli import SUBCOMMANDS  # noqa: PLC0415

    for missing in sorted(set(SUBCOMMANDS) - seen_subcommands):
        failures.append(f"repro.uvm.cli subcommand {missing!r} is documented nowhere in {[d.name for d in DOCS]}")

    if failures:
        print("docs drift detected:")
        for f in failures:
            print("  -", f)
        return 1
    print(f"docs ok: {len(cmds)} commands validated against --help, {len(helps)} modules probed, "
          f"{len(seen_subcommands)} cli subcommands documented")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
